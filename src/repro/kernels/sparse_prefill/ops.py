"""Public wrapper: hierarchical top-p block-sparse prefill attention.

``sparse_prefill_attend`` adapts both prefill layouts to the kernel's
``(B = b*hkv, nqb, q_block*group, d)`` tiling:

* **contiguous** — dense ``prefill``'s (b, n, hkv, d) K/V with per-batch
  Quest page metadata (b, n_pages, hkv, d); ``n`` must be padded to a
  page multiple (mask the tail via ``kv_len``);
* **pooled** — ``prefill_chunk``'s shared page pool (P, hkv, d) with the
  pool-resident metadata (num_pages, hkv, d) and a per-slot page table;
  ``kv_len``/``q_offset`` may be traced (the chunk walker's running
  position).

Selection happens here, not in the kernel: ``prefill_page_survivors``
max-reduces the Quest min/max upper bound over each query block (and its
GQA group), runs the existing ``page_nucleus_mask`` top-p search per
(query block, kv head), and forces the causal-frontier pages plus a
``recent_pages`` window — so every valid query row always keeps its own
page and the survivor set is monotone in ``p``.  The kernel then streams
only surviving pages (``kernel.sparse_prefill_rows``).

``top_p >= 1.0`` statically bypasses the whole machinery and runs the
dense oracle — **bit-for-bit** the model's plain ``mha_attention``
prefill, the same convention as ``page_top_p=1.0`` in the decode
pipeline.  Below budget (``sparse_prefill_fits``, the prefill twin of
``fused_fits``) or off-TPU, the jnp fallback applies the identical
survivor mask as an additive bias, so mask semantics never depend on the
backend.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.attention import mha_attention
from repro.core.selectors import gather_logical_rows, page_nucleus_mask
from repro.kernels.common import NEG_INF, resolve_interpret
from repro.kernels.fused_decode.kernel import coalesce_block
from repro.kernels.sparse_prefill.kernel import sparse_prefill_rows

# Per-core VMEM is ~16 MB; leave headroom for the compiler's own buffers.
SPARSE_PREFILL_VMEM_BUDGET = 12 << 20

# Queries per kernel tile.  256 keeps the (qr, blk) score tile MXU-shaped
# at group=4 and bounds the survivor-selection intermediate in the
# wrapper; chunk sizes and pad amounts are derived from it.
DEFAULT_Q_BLOCK = 256


def sparse_prefill_vmem_bytes(n: int, d: int, group: int,
                              kv_bytes: int = 2, *,
                              q_block: int = DEFAULT_Q_BLOCK,
                              page_size: int = 64) -> int:
    """Analytic VMEM working set of one (slot, kv-head, query-block) step.

    Terms, in kernel order: the f32 query tile; the survivor/row operands
    (nb = n/blk blocks); ~3 live (qr, blk) f32 score/mask tiles; the
    online-softmax accumulator (m/l/acc per query row); and the
    double-buffered K and V block staging scratch (2 buffers x 2 streams
    x blk rows).  Unlike the fused decode budget there is no O(m)
    candidate-codes term — the whole point of the query-block grid is
    that only one kv block is ever resident.
    """
    blk = coalesce_block(page_size, page_size)
    qr = q_block * group
    nb = -(-n // blk)
    queries = qr * d * 4
    operands = nb * (1 + 4) + 8
    score_tiles = 3 * qr * blk * 4
    accum = qr * (d + 2) * 4
    staging = 2 * 2 * blk * d * kv_bytes
    return queries + operands + score_tiles + accum + staging


def sparse_prefill_fits(n: int, d: int, group: int, kv_bytes: int = 2, *,
                        q_block: int = DEFAULT_Q_BLOCK,
                        page_size: int = 64,
                        interpret: bool | None = None) -> bool:
    """Static go/no-go for the sparse prefill kernel at this context.

    ``interpret=False`` forces the real budget check (interpret mode has
    no VMEM ceiling, so the default tri-state always fits off-TPU).
    """
    if resolve_interpret(interpret):
        return True
    return sparse_prefill_vmem_bytes(
        n, d, group, kv_bytes, q_block=q_block,
        page_size=page_size) <= SPARSE_PREFILL_VMEM_BUDGET


def prefill_page_survivors(
    q: jax.Array,  # (b, s_pad, hq, d) — s_pad a q_block multiple
    kmax: jax.Array,  # (b, n_pages, hkv, d) — Quest page maxima
    kmin: jax.Array,  # (b, n_pages, hkv, d)
    *,
    top_p: float,
    page_size: int,
    kv_len: jax.Array,  # (b,) i32 — resident prefix length (traced ok)
    q_offset: jax.Array,  # (b,) i32 — position of the first query row
    n_valid: jax.Array | None = None,  # (b,) true query count (pad excl.)
    q_block: int = DEFAULT_Q_BLOCK,
    iters: int = 24,
    recent_pages: int = 1,
) -> tuple[jax.Array, jax.Array]:
    """Page-survivor masks per query block: (survivors, participate),
    both (b, nqb, hkv, n_pages) bool.

    Per query block the Quest score upper bound ``relu(q)@kmax +
    min(q,0)@kmin`` is max-reduced over the block's queries and GQA group
    (block-union: a page any group member wants, the whole block keeps),
    then passed through ``page_nucleus_mask``.  Causal-frontier pages
    (those overlapping the block's own query positions) and the
    ``recent_pages`` window before them are kept unconditionally, so the
    nucleus can only prune the *prefix interior*.  ``participate``
    restricts everything to causally visible, resident pages; pad query
    rows (``>= n_valid``) are excluded from the block max.
    """
    b, s, hq, d = q.shape
    n_pages = kmax.shape[1]
    hkv = kmax.shape[2]
    group = hq // hkv
    nqb = s // q_block
    kmaxf = kmax.astype(jnp.float32)
    kminf = kmin.astype(jnp.float32)
    qf = q.astype(jnp.float32).reshape(b, nqb, q_block, hkv, group, d)
    if n_valid is None:
        row_valid = jnp.ones((b, s), bool)
    else:
        row_valid = jnp.arange(s, dtype=jnp.int32)[None, :] < n_valid[:, None]
    rv = row_valid.reshape(b, nqb, q_block)

    # One query block at a time: the (b, q_block, hq, n_pages) upper-bound
    # tile is the only O(s * n_pages) intermediate, and lax.map keeps it
    # to a single block's worth of memory.
    def block_scores(args):
        qb, rvb = args  # (b, q_block, hkv, group, d), (b, q_block)
        ub = jnp.einsum("btkgd,bpkd->btkgp", jnp.maximum(qb, 0.0), kmaxf)
        ub += jnp.einsum("btkgd,bpkd->btkgp", jnp.minimum(qb, 0.0), kminf)
        ub = jnp.where(rvb[:, :, None, None, None], ub, NEG_INF)
        return ub.max(axis=(1, 3))  # (b, hkv, n_pages)

    scores = jax.lax.map(
        block_scores,
        (qf.transpose(1, 0, 2, 3, 4, 5), rv.transpose(1, 0, 2)))
    scores = scores.transpose(1, 0, 2, 3)  # (b, nqb, hkv, n_pages)

    kv_len = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (b,))
    q_offset = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (b,))
    qlo = q_offset[:, None] + jnp.arange(nqb, dtype=jnp.int32) * q_block
    qhi = qlo + q_block - 1  # (b, nqb)
    pstart = jnp.arange(n_pages, dtype=jnp.int32) * page_size
    participate = ((pstart[None, None, :] <= qhi[..., None])
                   & (pstart[None, None, :] < kv_len[:, None, None]))
    # Frontier pages (overlapping this block's own queries, clamped to
    # the resident prefix) + the recent window are kept unconditionally.
    flo = jnp.maximum(qlo // page_size - recent_pages, 0)
    fhi = jnp.minimum(qhi, kv_len[:, None] - 1) // page_size
    pidx = jnp.arange(n_pages, dtype=jnp.int32)
    forced = ((pidx[None, None, :] >= flo[..., None])
              & (pidx[None, None, :] <= fhi[..., None]))

    part_h = jnp.broadcast_to(
        participate[:, :, None, :], (b, nqb, hkv, n_pages))
    keep = page_nucleus_mask(scores, part_h, top_p, iters=iters)
    survivors = (keep | forced[:, :, None, :]) & part_h
    return survivors, part_h


def sparse_prefill_attend(
    q: jax.Array,  # (b, s, hq, d)
    keys: jax.Array,  # (b, n, hkv, d) contiguous or (P, hkv, d) pooled
    values: jax.Array,  # same layout as keys
    kmax: jax.Array,  # (b, n_pages, hkv, d) or pool meta (num_pages, hkv, d)
    kmin: jax.Array,  # same layout as kmax
    *,
    top_p: float,
    page_size: int,
    kv_len: jax.Array | int | None = None,
    q_offset: jax.Array | int = 0,
    n_valid: jax.Array | None = None,
    page_table: jax.Array | None = None,  # (b, max_pages) i32 — pooled
    q_block: int = DEFAULT_Q_BLOCK,
    iters: int = 24,
    recent_pages: int = 1,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
    return_aux: bool = False,
):
    """Hierarchical top-p sparse prefill: (b, s, hq, d) output.

    ``use_kernel=None`` resolves to the Pallas kernel on a real TPU and
    the jnp bias path elsewhere; either way the kernel falls back when
    ``sparse_prefill_fits`` says the tile would overflow VMEM.  With
    ``return_aux=True`` also returns ``{"survivors", "participate"}``
    (both (b, nqb, hkv, n_pages) bool) for live-page telemetry.
    """
    b, s, hq, d = q.shape
    pooled = keys.ndim == 3
    if pooled:
        if page_table is None:
            raise ValueError("pooled K/V need a page_table")
        n = page_table.shape[1] * page_size
        kmaxg = jnp.take(kmax, page_table, axis=0)  # (b, max_pages, hkv, d)
        kming = jnp.take(kmin, page_table, axis=0)
    else:
        n = keys.shape[1]
        if n % page_size:
            raise ValueError(f"n={n} not a page_size={page_size} multiple")
        kmaxg, kming = kmax, kmin
    hkv = kmaxg.shape[2]
    group = hq // hkv
    n_pages = n // page_size
    if kv_len is None:
        kv_len = n
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (b,))
    off = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (b,))

    if top_p >= 1.0:
        # Statically dense: bit-for-bit the plain mha_attention prefill
        # (the decode pipeline's page_top_p=1.0 convention).  Both call
        # sites have a uniform query offset across the batch (contiguous
        # prefill: 0; the chunk walker runs one slot at a time), so
        # off[0] is exact here.
        if pooled:
            k_log = gather_logical_rows(keys, page_table, page_size)
            v_log = gather_logical_rows(values, page_table, page_size)
        else:
            k_log, v_log = keys, values
        out = mha_attention(q, k_log, v_log, causal=True, q_offset=off[0])
        if return_aux:
            part = ((jnp.arange(n_pages) * page_size)[None, None, None, :]
                    < kv_len[:, None, None, None])
            part = jnp.broadcast_to(part, (b, 1, hkv, n_pages))
            return out, {"survivors": part, "participate": part}
        return out

    pad = (-s) % q_block
    q_pad = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s_pad = s + pad
    nqb = s_pad // q_block
    if n_valid is None:
        n_valid = jnp.full((b,), s, jnp.int32)
    else:
        n_valid = jnp.broadcast_to(jnp.asarray(n_valid, jnp.int32), (b,))
    survivors, participate = prefill_page_survivors(
        q_pad, kmaxg, kming, top_p=top_p, page_size=page_size,
        kv_len=kv_len, q_offset=off, n_valid=n_valid, q_block=q_block,
        iters=iters, recent_pages=recent_pages)

    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    use_kernel = use_kernel and sparse_prefill_fits(
        n, d, group, keys.dtype.itemsize, q_block=q_block,
        page_size=page_size, interpret=interpret)

    if use_kernel:
        blk = coalesce_block(page_size, page_size)
        sub = page_size // blk
        nb = n_pages * sub
        surv_b = jnp.repeat(survivors, sub, axis=3)  # page -> sub-blocks
        surv_b = surv_b.transpose(0, 2, 1, 3).reshape(b * hkv, nqb, nb)
        if pooled:
            base = page_table.astype(jnp.int32) * page_size  # (b, max_pages)
            rows = (base[..., None]
                    + jnp.arange(0, page_size, blk, dtype=jnp.int32))
            rows = rows.reshape(b, nb)
        else:
            rows = jnp.broadcast_to(
                jnp.arange(nb, dtype=jnp.int32) * blk, (b, nb))
        rows = jnp.broadcast_to(rows[:, None], (b, hkv, nb)).reshape(-1, nb)
        kv_b = jnp.broadcast_to(
            kv_len[:, None], (b, hkv)).reshape(-1, 1)
        off_b = jnp.broadcast_to(off[:, None], (b, hkv)).reshape(-1, 1)
        qk = q_pad.reshape(b, nqb, q_block, hkv, group, d)
        qk = qk.transpose(0, 3, 1, 2, 4, 5)
        qk = qk.reshape(b * hkv, nqb, q_block * group, d)
        out = sparse_prefill_rows(
            qk, surv_b, rows, kv_b, off_b, keys, values,
            sm_scale=1.0 / math.sqrt(d), hkv=hkv, group=group,
            q_block=q_block, pooled=pooled, page_size=page_size,
            interpret=interpret)
        out = out.reshape(b, hkv, nqb, q_block, group, d)
        out = out.transpose(0, 2, 3, 1, 4, 5).reshape(b, s_pad, hq, d)
    else:
        # jnp fallback: identical survivor mask, applied as an additive
        # finite bias through the dense prefill attention.
        if pooled:
            k_log = gather_logical_rows(keys, page_table, page_size)
            v_log = gather_logical_rows(values, page_table, page_size)
        else:
            k_log, v_log = keys, values
        allow = jnp.repeat(survivors, q_block, axis=1)  # (b, s_pad, hkv, np)
        allow = jnp.repeat(allow, page_size, axis=3)  # (b, s_pad, hkv, n)
        bias = jnp.where(allow.transpose(0, 2, 1, 3), 0.0, NEG_INF)
        bias = jnp.repeat(bias, group, axis=1)  # (b, hq, s_pad, n)
        klive = jnp.arange(n, dtype=jnp.int32)[None, :] < kv_len[:, None]
        bias = jnp.where(klive[:, None, None, :], bias, NEG_INF)
        out = mha_attention(q_pad, k_log, v_log, causal=True,
                            q_offset=off[0], bias=bias)

    out = out[:, :s]
    if return_aux:
        return out, {"survivors": survivors, "participate": participate}
    return out
