"""Recurrent mixers: chunked == sequential; decode-step == scan step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib


@pytest.fixture()
def mamba_setup(rng):
    cfg = get_smoke_config("jamba-1.5-large-398b")
    params = ssm_lib.mamba_init(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(2, 96, cfg.d_model)) * 0.5, jnp.float32)
    return cfg, params, x


def test_mamba_chunked_equals_sequential(mamba_setup):
    cfg, params, x = mamba_setup
    a = ssm_lib.mamba_apply(params, cfg, x)
    b, st = ssm_lib.mamba_apply(params, cfg, x, chunked=True, chunk=32,
                                return_state=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_mamba_decode_continues_prefill(mamba_setup):
    cfg, params, x = mamba_setup
    full = ssm_lib.mamba_apply(params, cfg, x)
    _, st = ssm_lib.mamba_apply(params, cfg, x[:, :64], return_state=True)
    outs = []
    state = st
    for t in range(64, 96):
        o, state = ssm_lib.mamba_decode_step(params, cfg, x[:, t], state)
        outs.append(o)
    dec = np.stack([np.asarray(o) for o in outs], axis=1)
    np.testing.assert_allclose(dec, np.asarray(full[:, 64:96]), atol=1e-4)


def test_mlstm_chunked_equals_sequential(rng):
    cfg = get_smoke_config("xlstm-350m")
    params = xlstm_lib.mlstm_init(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(2, 96, cfg.d_model)) * 0.5, jnp.float32)
    a = xlstm_lib.mlstm_apply(params, cfg, x, chunk=10**9)
    b = xlstm_lib.mlstm_apply(params, cfg, x, chunk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_mlstm_decode_continues_prefill(rng):
    cfg = get_smoke_config("xlstm-350m")
    params = xlstm_lib.mlstm_init(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(1, 48, cfg.d_model)) * 0.5, jnp.float32)
    full = xlstm_lib.mlstm_apply(params, cfg, x, chunk=10**9)
    _, st = xlstm_lib.mlstm_apply(params, cfg, x[:, :32], chunk=10**9,
                                  return_state=True)
    outs = []
    state = st
    for t in range(32, 48):
        o, state = xlstm_lib.mlstm_decode_step(params, cfg, x[:, t], state)
        outs.append(o)
    dec = np.stack([np.asarray(o) for o in outs], axis=1)
    np.testing.assert_allclose(dec, np.asarray(full[:, 32:48]), atol=1e-4)


def test_slstm_decode_continues_prefill(rng):
    cfg = get_smoke_config("xlstm-350m")
    params = xlstm_lib.slstm_init(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(1, 48, cfg.d_model)) * 0.5, jnp.float32)
    full = xlstm_lib.slstm_apply(params, cfg, x)
    _, st = xlstm_lib.slstm_apply(params, cfg, x[:, :32], return_state=True)
    outs = []
    state = st
    for t in range(32, 48):
        o, state = xlstm_lib.slstm_decode_step(params, cfg, x[:, t], state)
        outs.append(o)
    dec = np.stack([np.asarray(o) for o in outs], axis=1)
    np.testing.assert_allclose(dec, np.asarray(full[:, 32:48]), atol=1e-4)


def test_mamba_state_decay_bounded(mamba_setup):
    """SSM state must not blow up over long rollouts (A < 0)."""
    cfg, params, x = mamba_setup
    state = ssm_lib.mamba_init_state(cfg, 2)
    for t in range(64):
        _, state = ssm_lib.mamba_decode_step(params, cfg, x[:, t % 96], state)
    assert np.isfinite(np.asarray(state["ssm"])).all()
    assert np.abs(np.asarray(state["ssm"])).max() < 1e4
