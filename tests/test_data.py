"""Data pipeline: determinism, structure, needle embedding."""

import numpy as np

from repro.data import DataConfig, needle_batch, synthetic_lm_batches


def test_lm_batches_deterministic():
    cfg = DataConfig(vocab_size=512, seq_len=64, global_batch=4, seed=3)
    a = list(synthetic_lm_batches(cfg, 2))
    b = list(synthetic_lm_batches(cfg, 2))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
        np.testing.assert_array_equal(x["labels"], y["labels"])


def test_lm_batch_shapes_and_shift():
    cfg = DataConfig(vocab_size=512, seq_len=64, global_batch=4)
    batch = next(iter(synthetic_lm_batches(cfg, 1)))
    assert batch["tokens"].shape == (4, 64)
    assert batch["labels"].shape == (4, 64)
    assert (batch["tokens"] < 512).all() and (batch["tokens"] >= 0).all()


def test_lm_has_learnable_structure():
    """Markov phrases: next-token entropy must be well below uniform."""
    cfg = DataConfig(vocab_size=512, seq_len=2048, global_batch=8, seed=0)
    batch = next(iter(synthetic_lm_batches(cfg, 1)))
    toks = batch["tokens"]
    # Bigram predictability: fraction of (t, t+1) pairs seen >= 3 times.
    pairs = toks[:, :-1].astype(np.int64) * 512 + toks[:, 1:]
    _, counts = np.unique(pairs, return_counts=True)
    repeated = counts[counts >= 3].sum() / pairs.size
    assert repeated > 0.3, f"too little structure: {repeated}"


def test_needle_batch():
    cfg = DataConfig(vocab_size=512, seq_len=256, global_batch=8, seed=1)
    rng = np.random.default_rng(1)
    batch = needle_batch(cfg, rng, 8)
    toks, ans = batch["tokens"], batch["answers"]
    assert toks.shape == (8, 256) and ans.shape == (8,)
    for i in range(8):
        assert toks[i, -2] == 2  # QUERY_MARK
        key = toks[i, -1]
        # The key appears right after a KEY_MARK, followed by the answer.
        marks = np.where(toks[i, :-2] == 1)[0]
        found = [m for m in marks if toks[i, m + 1] == key]
        assert found, "needle key must exist in context"
        assert toks[i, found[0] + 2] == ans[i]
