"""Pallas kernel: score estimation q · K̃ᵀ over the packed INT4 K cache.

This is the TPU adaptation of the paper's SpGEMV (§4.2, Appendix B.1).  The
GPU version dequantizes INT4 -> FP16 in shared memory with PTX tricks; here
the dequantization is *folded into the matmul epilogue* instead of
materializing K̃:

    k_c      = code_c * scale_tok + zero_tok                  (per channel c)
    q · k    = scale_tok * (q · code) + zero_tok * Σ_c q_c

so the kernel does two integer-code matmuls on the MXU (even channels from
the low nibbles, odd channels from the high nibbles — queries arrive
pre-de-interleaved, avoiding any in-kernel lane shuffles) plus a rank-1 VPU
epilogue.  HBM traffic is the packed nibble buffer: d/2 bytes per token, the
paper's ≤1/4 data-access claim.

Grid: (B, n // block_n) where B = batch * kv_heads; each grid step stages a
(block_n, d/2) uint8 tile of the packed cache into VMEM.

Hierarchical page nucleus (``TwilightConfig.page_top_p``): the optional
per-block ``live`` operand marks blocks with at least one live candidate
slot.  A dead block — a whole block of nucleus-pruned pages — skips both
matmuls and the epilogue behind ``pl.when`` and writes zeros, so the
estimate's compute scales with the *surviving* candidate count, not the
static buffer capacity.  Dead-slot scores are unspecified by contract
(consumers mask on ``valid`` before the softmax), so zeros are safe.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import resolve_interpret


def _spgemv_kernel(qe_ref, qo_ref, packed_ref, scale_ref, zero_ref, live_ref,
                   out_ref, *, sm_scale: float):
    @pl.when(live_ref[0, 0] != 0)
    def _compute():
        qe = qe_ref[0].astype(jnp.float32)  # (group, d2)
        qo = qo_ref[0].astype(jnp.float32)
        codes = packed_ref[0]  # (block_n, d2) uint8
        low = (codes & 0x0F).astype(jnp.float32)
        high = (codes >> 4).astype(jnp.float32)
        scale = scale_ref[0].astype(jnp.float32)  # (block_n,)
        zero = zero_ref[0].astype(jnp.float32)
        # MXU: (group, d2) x (d2, block_n)
        dot = jnp.dot(qe, low.T, preferred_element_type=jnp.float32)
        dot += jnp.dot(qo, high.T, preferred_element_type=jnp.float32)
        qsum = jnp.sum(qe + qo, axis=-1, keepdims=True)  # (group, 1)
        scores = dot * scale[None, :] + qsum * zero[None, :]
        out_ref[0] = scores * sm_scale

    @pl.when(live_ref[0, 0] == 0)
    def _dead():
        out_ref[0] = jnp.zeros_like(out_ref[0])


@functools.partial(
    jax.jit, static_argnames=("sm_scale", "block_n", "interpret")
)
def spgemv_scores(
    q_even: jax.Array,  # (B, group, d//2) f32/bf16 — even channels of q
    q_odd: jax.Array,  # (B, group, d//2)
    packed: jax.Array,  # (B, n, d//2) uint8 — INT4 K codes
    scale: jax.Array,  # (B, n) f32
    zero: jax.Array,  # (B, n) f32
    valid: jax.Array | None = None,  # (B, n) bool — live candidate slots
    *,
    sm_scale: float,
    block_n: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Estimated attention scores (B, group, n) in f32.

    ``valid`` enables the dead-block early-out: blocks of ``block_n`` slots
    with no live candidate write zeros without touching the MXU.  ``None``
    scores every slot (the flat pipeline).
    """
    interpret = resolve_interpret(interpret)
    B, group, d2 = q_even.shape
    n = packed.shape[1]
    block_n = min(block_n, n)
    while n % block_n:
        block_n -= 1
    nb = n // block_n
    if valid is None:
        live = jnp.ones((B, nb), jnp.int32)
    else:
        live = valid.reshape(B, nb, block_n).any(axis=-1).astype(jnp.int32)
    grid = (B, nb)
    return pl.pallas_call(
        functools.partial(_spgemv_kernel, sm_scale=sm_scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, group, d2), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, group, d2), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, block_n, d2), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, group, block_n), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((B, group, n), jnp.float32),
        interpret=interpret,
    )(q_even, q_odd, packed, scale, zero, live)
