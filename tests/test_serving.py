"""Serving engine + sampler."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.serving import DecodeEngine, Request, top_p_sample


def test_top_p_sample_restricts_support(rng):
    logits = jnp.asarray([[10.0, 9.5, 0.0, -5.0, -5.0]] * 64)
    keys = jax.random.split(jax.random.PRNGKey(0), 64)
    toks = np.asarray(jax.vmap(
        lambda k, l: top_p_sample(k, l[None], p=0.8)[0])(keys, logits))
    assert set(toks.tolist()) <= {0, 1}, "p=0.8 keeps only the two top tokens"


def test_greedy_sample():
    from repro.serving.sampler import sample_token
    logits = jnp.asarray([[0.0, 3.0, 1.0]])
    tok = sample_token(jax.random.PRNGKey(0), logits, greedy=True)
    assert int(tok[0]) == 1


def test_engine_generates(rng):
    cfg = get_smoke_config("qwen2-1.5b")
    engine = DecodeEngine(cfg, batch_size=2, cache_capacity=64)
    reqs = [Request(uid=i,
                    prompt=rng.integers(8, cfg.vocab_size, 24).astype(np.int32),
                    max_new_tokens=4) for i in range(3)]
    results = engine.generate(reqs)
    assert len(results) == 3
    for r in results:
        assert len(r.tokens) == 4
        assert all(0 <= t < cfg.vocab_size for t in r.tokens)
        assert r.mean_pruned_budget > 0


def test_engine_greedy_deterministic(rng):
    cfg = get_smoke_config("qwen2-1.5b")
    engine = DecodeEngine(cfg, batch_size=2, cache_capacity=64, seed=7)
    prompt = rng.integers(8, cfg.vocab_size, 24).astype(np.int32)
    r1 = engine.generate([Request(uid=0, prompt=prompt, max_new_tokens=6)])
    r2 = engine.generate([Request(uid=1, prompt=prompt, max_new_tokens=6)])
    assert r1[0].tokens == r2[0].tokens


def test_engine_vlm(rng):
    cfg = get_smoke_config("internvl2-1b")
    engine = DecodeEngine(cfg, batch_size=2, cache_capacity=64)
    reqs = [Request(
        uid=0, prompt=rng.integers(8, cfg.vocab_size, 16).astype(np.int32),
        max_new_tokens=3,
        extras={"patches": rng.normal(
            size=(cfg.n_prefix_tokens, cfg.d_model)).astype(np.float32)})]
    results = engine.generate(reqs)
    assert len(results[0].tokens) == 3
