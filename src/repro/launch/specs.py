"""Input shapes, ShapeDtypeStruct stand-ins, and jit-able step builders.

The four assigned input shapes map to three step kinds:

  train_4k    -> train_step(params, opt_state, batch)
  prefill_32k -> prefill(params, batch) -> (logits, decode_state)
  decode_32k  -> decode_step(params, state, token)   (KV cache = 32k)
  long_500k   -> decode_step(params, state, token)   (KV cache = 512k)

Everything here is ShapeDtypeStruct-only (weak-type-correct, shardable, no
allocation); the dry-run lowers and compiles against these.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import (
    decode_step,
    decode_step_paged,
    forward,
    init_decode_state,
    init_paged_decode_state,
    init_params,
    prefill,
)
from repro.models.common import ModelConfig
from repro.optim import adamw_init
from repro.sharding import (
    MeshAxes,
    batch_specs,
    decode_state_specs,
    paged_decode_state_specs,
    param_specs,
)
from repro.sharding.act import activation_rules
from repro.training import TrainConfig, make_train_step

Tree = Any


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", "train", 4096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32768, 128),
    "long_500k": InputShape("long_500k", "decode", 524288, 1),
    # Continuous-batching serving shape: decode_step_paged over the shared
    # page pool (seq_len = per-slot capacity; the pool itself is sized by
    # paged_pool_pages and sharded over `model`, see
    # sharding.rules.paged_decode_state_specs for the page-id remap).
    "decode_paged_32k": InputShape("decode_paged_32k", "decode_paged",
                                   32768, 128),
}

# Fixed encoder memory length for the enc-dec arch in decode shapes (the
# decoder self-KV carries the full seq_len; see DESIGN.md).
ENC_MEMORY_LEN = 4096


def _struct(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def batch_struct(cfg: ModelConfig, shape: InputShape, *, with_labels: bool
                 ) -> dict[str, jax.ShapeDtypeStruct]:
    """Host-batch ShapeDtypeStructs for train/prefill."""
    b, s = shape.global_batch, shape.seq_len
    text = s - cfg.n_prefix_tokens if cfg.frontend == "vision" else s
    out = {"tokens": _struct((b, text), jnp.int32)}
    if with_labels:
        out["labels"] = _struct((b, text), jnp.int32)
    if cfg.frontend == "audio":
        out["frames"] = _struct((b, s, cfg.d_model), jnp.float32)
    elif cfg.frontend == "vision":
        out["patches"] = _struct((b, cfg.n_prefix_tokens, cfg.d_model),
                                 jnp.float32)
    return out


def params_struct(cfg: ModelConfig) -> Tree:
    return jax.eval_shape(functools.partial(init_params, cfg),
                          jax.random.PRNGKey(0))


def decode_state_struct(cfg: ModelConfig, shape: InputShape) -> Tree:
    n_enc = ENC_MEMORY_LEN if cfg.encoder_layers else 0
    return jax.eval_shape(functools.partial(
        init_decode_state, cfg, shape.global_batch, shape.seq_len,
        n_enc=n_enc))


def paged_pool_pages(cfg: ModelConfig, shape: InputShape) -> int:
    """Pool size for the paged serving shape: worst case (every slot at its
    per-slot capacity) + the null page, rounded up to 512 so the pool's
    page dim divides evenly over any production `model` axis size."""
    max_pages = shape.seq_len // cfg.twilight.page_size
    want = 1 + shape.global_batch * max_pages
    return -(-want // 512) * 512


def paged_decode_state_struct(cfg: ModelConfig, shape: InputShape) -> Tree:
    n_enc = ENC_MEMORY_LEN if cfg.encoder_layers else 0
    return jax.eval_shape(functools.partial(
        init_paged_decode_state, cfg, shape.global_batch,
        paged_pool_pages(cfg, shape), n_enc=n_enc))


@dataclasses.dataclass
class StepPlan:
    """Everything the dry-run needs to lower one (arch, shape, mesh) cell."""

    fn: Callable
    arg_structs: tuple
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()


def activation_rule_set(cfg: ModelConfig, mesh, axes: MeshAxes,
                        *, seq_len: int = 0, seq_parallel: bool = True) -> dict:
    """Logical activation shardings installed while tracing the step.

    ``seq_parallel`` shards the residual's sequence dim over the tensor
    axis between blocks (Megatron-SP): the 28-deep saved-residual stack of
    the remat scan drops by 16x per device, at the cost of
    gather/scatter collectives around each block's matmuls.
    """
    t = mesh.shape[axes.tensor]
    vocab_ax = axes.tensor if cfg.padded_vocab % t == 0 else None
    heads_ax = axes.tensor if cfg.n_heads % t == 0 else None
    kv_heads_ax = axes.tensor if cfg.n_kv_heads % t == 0 else None
    seq_ax = (axes.tensor
              if seq_parallel and seq_len and seq_len % t == 0 else None)
    rules = {
        "residual": P(axes.batch, seq_ax, None),
        "logits": P(axes.batch, None, vocab_ax),
        "heads": P(axes.batch, None, heads_ax, None),
        "kv_heads": P(axes.batch, None, kv_heads_ax, None),
    }
    if cfg.moe is not None:
        # Shard-local dispatch: groups over the batch axes, experts over the
        # tensor axis; gathers/scatters stay group-local (see moe_apply).
        e_ax = axes.tensor if cfg.moe.n_experts % t == 0 else None
        rules["moe_shards"] = 1  # overwritten by build_step_plan
        rules["moe_tokens"] = P(axes.batch, None, None)
        rules["moe_dispatch"] = P(axes.batch, e_ax, None, None)
    if cfg.ssm is not None:
        d_inner = cfg.ssm.expand * cfg.d_model
        inner_ax = axes.tensor if d_inner % t == 0 else None
        rules["ssm_inner"] = P(axes.batch, None, inner_ax, None)
        rules["ssm_y"] = P(axes.batch, None, inner_ax)
    return rules


def _with_rules(fn, rules):
    def wrapped(*args):
        with activation_rules(rules):
            return fn(*args)
    return wrapped


def build_step_plan(cfg: ModelConfig, shape: InputShape,
                    mesh: jax.sharding.Mesh,
                    overrides: dict | None = None) -> StepPlan:
    """Overrides (the §Perf hillclimb knobs):
      seq_parallel: bool — force Megatron-SP residuals on/off
      no_act_rules: bool — drop all activation constraints (XLA free choice)
      grad_accum:   int  — force the microbatch count
      param_layout: "fsdp" | "model_only"
      twilight:     dict — dataclasses.replace fields on cfg.twilight
    """
    ov = overrides or {}
    if ov.get("twilight"):
        import dataclasses as _dc
        cfg = cfg.replace(twilight=_dc.replace(cfg.twilight, **ov["twilight"]))
    axes = MeshAxes.for_mesh(mesh)
    ns = lambda spec: NamedSharding(mesh, spec)  # noqa: E731
    tree_ns = lambda specs: jax.tree_util.tree_map(  # noqa: E731
        ns, specs, is_leaf=lambda x: isinstance(x, P))
    seq_for_rules = shape.seq_len if shape.kind in ("train", "prefill") else 0
    if cfg.frontend == "vision":
        seq_for_rules = 0  # prefix+text concat: keep batch-only sharding
    if cfg.ssm is not None or cfg.xlstm is not None:
        # Recurrent blocks scan over time and shard their inner width over
        # the tensor axis instead — sequence-parallel residuals would fight
        # them for the same axis (measured: 2.3 TB of all-gathers on Jamba).
        seq_for_rules = 0
    if ov.get("seq_parallel") is False:
        seq_for_rules = 0
    rules = activation_rule_set(cfg, mesh, axes, seq_len=seq_for_rules)
    if cfg.moe is not None:
        fsdp_size = _axes_size(axes.batch, mesh)
        if shape.global_batch % fsdp_size == 0:
            rules["moe_shards"] = fsdp_size
    if ov.get("no_act_rules"):
        rules = {k: v for k, v in rules.items() if not isinstance(v, P)}

    p_struct = params_struct(cfg)
    p_specs = param_specs(p_struct, cfg, mesh,
                          layout=ov.get("param_layout", "fsdp"))

    if shape.kind == "train":
        o_struct = jax.eval_shape(adamw_init, p_struct)
        o_specs = {"m": p_specs, "v": p_specs, "step": P()}
        b_struct = batch_struct(cfg, shape, with_labels=True)
        b_specs = batch_specs(b_struct, axes)
        n_params = sum(x.size for x in jax.tree_util.tree_leaves(p_struct))
        accum = ov.get("grad_accum", 0)
        if not accum:
            accum = 1
            if n_params > 100e9:
                accum = 8
            elif n_params > 20e9:
                accum = 2
        while shape.global_batch % (accum * _axes_size(axes.batch, mesh)):
            accum //= 2
        tcfg = TrainConfig(remat=True, grad_accum=max(1, accum))
        step = make_train_step(cfg, tcfg)
        metrics_specs = {k: P() for k in
                         ("loss", "ce", "moe_aux", "ppl", "grad_norm", "lr")}
        return StepPlan(
            fn=_with_rules(step, rules),
            arg_structs=(p_struct, o_struct, b_struct),
            in_shardings=(tree_ns(p_specs), tree_ns(o_specs), tree_ns(b_specs)),
            out_shardings=(tree_ns(p_specs), tree_ns(o_specs),
                           tree_ns(metrics_specs)),
            donate_argnums=(0, 1),
        )

    if shape.kind == "prefill":
        b_struct = batch_struct(cfg, shape, with_labels=False)
        b_specs = batch_specs(b_struct, axes)
        st_struct = decode_state_struct(cfg, shape)
        st_specs = decode_state_specs(st_struct, cfg, mesh,
                                      batch=shape.global_batch,
                                      capacity=shape.seq_len)
        logits_sp = P(axes.batch, None,
                      "model" if cfg.padded_vocab % mesh.shape["model"] == 0
                      else None)

        def fn(params, batch):
            return prefill(params, cfg, batch, shape.seq_len)

        return StepPlan(
            fn=_with_rules(fn, rules),
            arg_structs=(p_struct, b_struct),
            in_shardings=(tree_ns(p_specs), tree_ns(b_specs)),
            out_shardings=(ns(logits_sp), tree_ns(st_specs)),
        )

    if shape.kind == "decode_paged":
        # Continuous-batching decode over the shared page pool: the pool
        # shards over `model` (whole pages per shard, see
        # paged_decode_state_specs); page tables / lengths / live masks are
        # small per-slot data sharded over the batch axes.
        bsz = shape.global_batch
        max_pages = shape.seq_len // cfg.twilight.page_size
        num_pages = paged_pool_pages(cfg, shape)
        st_struct = paged_decode_state_struct(cfg, shape)
        st_specs = paged_decode_state_specs(st_struct, cfg, mesh,
                                            batch=bsz, num_pages=num_pages)
        b_ax = (axes.batch
                if bsz % _axes_size(axes.batch, mesh) == 0 and bsz > 1
                else None)
        logits_sp = P(b_ax,
                      "model" if cfg.padded_vocab % mesh.shape["model"] == 0
                      else None)

        def fn(params, state, token, pt, lengths, live):
            return decode_step_paged(params, cfg, state, token, pt,
                                     lengths, live)

        return StepPlan(
            fn=_with_rules(fn, rules),
            arg_structs=(p_struct, st_struct,
                         _struct((bsz,), jnp.int32),
                         _struct((bsz, max_pages), jnp.int32),
                         _struct((bsz,), jnp.int32),
                         _struct((bsz,), jnp.bool_)),
            in_shardings=(tree_ns(p_specs), tree_ns(st_specs), ns(P(b_ax)),
                          ns(P(b_ax, None)), ns(P(b_ax)), ns(P(b_ax))),
            out_shardings=(ns(logits_sp), tree_ns(st_specs),
                           tree_ns({"pruned_budget": P(b_ax)})),
            donate_argnums=(1,),
        )

    # decode
    st_struct = decode_state_struct(cfg, shape)
    st_specs = decode_state_specs(st_struct, cfg, mesh,
                                  batch=shape.global_batch,
                                  capacity=shape.seq_len,
                                  kv_seq_shard=ov.get("kv_seq_shard", True))
    tok_struct = _struct((shape.global_batch,), jnp.int32)
    b_ax = (axes.batch
            if shape.global_batch % _axes_size(axes.batch, mesh) == 0
            and shape.global_batch > 1 else None)
    logits_sp = P(b_ax, "model" if cfg.padded_vocab % mesh.shape["model"] == 0
                  else None)

    def fn(params, state, token):
        return decode_step(params, cfg, state, token)

    stats_specs = {"mean_pruned_budget": P()}
    return StepPlan(
        fn=_with_rules(fn, rules),
        arg_structs=(p_struct, st_struct, tok_struct),
        in_shardings=(tree_ns(p_specs), tree_ns(st_specs), ns(P(b_ax))),
        out_shardings=(ns(logits_sp), tree_ns(st_specs), tree_ns(stats_specs)),
        donate_argnums=(1,),
    )


def _axes_size(axes_names, mesh) -> int:
    size = 1
    names = axes_names if isinstance(axes_names, tuple) else (axes_names,)
    for a in names:
        if a is not None:
            size *= mesh.shape[a]
    return size


def eligible(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """Arch × shape applicability (DESIGN §5).

    Every pair is eligible here: dense archs run long_500k via Twilight's
    bounded-candidate sparse decode (the paper's technique), SSM/hybrid run
    it natively.  Kept as a function so future encoder-only archs can skip.
    """
    del cfg, shape
    return True, ""
