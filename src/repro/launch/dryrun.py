import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

The two lines above MUST stay first (before any jax import) — jax locks the
device count at first init, and only the dry-run wants 512 placeholder
devices.

For each cell this prints/records:
  * memory_analysis()  — per-device bytes (proves the config fits),
  * cost_analysis()    — FLOPs / bytes accessed (roofline numerator),
  * collective bytes   — parsed from the optimized HLO per collective kind.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out results/dryrun
"""

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import get_config, list_archs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import (  # noqa: E402
    INPUT_SHAPES,
    build_step_plan,
    eligible,
)

_COLLECTIVE_RE = re.compile(
    r"=\s*(\S+?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.IGNORECASE)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 0.5, "u4": 0.5,
}


def _type_bytes(type_str: str) -> int:
    """Bytes of an HLO result type, incl. tuple types."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return int(total)


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-operand bytes of every collective op in the optimized HLO."""
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2).lower()
        out[kind] = out.get(kind, 0) + _type_bytes(type_str)
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             *, verbose: bool = True, overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, why = eligible(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh:
        plan = build_step_plan(cfg, shape, mesh, overrides=overrides)
        jitted = jax.jit(plan.fn,
                         in_shardings=plan.in_shardings,
                         out_shardings=plan.out_shardings,
                         donate_argnums=plan.donate_argnums)
        lowered = jitted.lower(*plan.arg_structs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        coll = collective_bytes(compiled.as_text())

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": mesh.devices.size,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops": float(cost.get("flops", -1.0)) if cost else -1.0,
        "bytes_accessed": float(cost.get("bytes accessed", -1.0)) if cost else -1.0,
        "collective_bytes": coll,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
    }
    if verbose:
        gb = 1 << 30
        peak = result["memory"]["temp_bytes"]
        print(f"[dryrun] {arch:24s} {shape_name:12s} mesh={result['mesh']:8s} "
              f"compile={t_compile:6.1f}s flops={result['flops']:.3e} "
              f"temp={0 if peak is None else peak / gb:.2f}GiB "
              f"coll={ {k: round(v / gb, 3) for k, v in coll.items()} }")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--all", action="store_true", help="all archs × shapes")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=None, help="write JSONL results here")
    ap.add_argument("--override", action="append", default=[],
                    help="hillclimb knob, e.g. seq_parallel=false, "
                         "grad_accum=4, param_layout=model_only, "
                         "twilight.p=0.9")
    args = ap.parse_args()

    overrides: dict = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        if v.lower() in ("true", "false"):
            v = v.lower() == "true"
        elif v.isdigit():
            v = int(v)
        else:
            try:
                v = float(v)
            except ValueError:
                pass
        if k.startswith("twilight."):
            overrides.setdefault("twilight", {})[k.split(".", 1)[1]] = v
        else:
            overrides[k] = v

    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for multi_pod in meshes:
                try:
                    results.append(run_cell(arch, shape_name, multi_pod,
                                            overrides=overrides or None))
                except Exception as e:  # noqa: BLE001 — report, keep going
                    failures += 1
                    print(f"[dryrun] FAIL {arch} {shape_name} "
                          f"multi_pod={multi_pod}: {e}")
                    traceback.print_exc()
                    results.append({"arch": arch, "shape": shape_name,
                                    "mesh": "2x16x16" if multi_pod else "16x16",
                                    "error": str(e)})
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out + ".jsonl", "w") as f:
            for r in results:
                f.write(json.dumps(r) + "\n")
        print(f"[dryrun] wrote {len(results)} results to {args.out}.jsonl")
    print(f"[dryrun] done: {len(results) - failures}/{len(results)} cells OK")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
