"""Dependency-free checkpointing: flattened pytree -> npz.

Tree paths become npz keys ("blocks/0/mixer/wq"), dtypes (incl. bfloat16,
stored as uint16 views with a dtype sidecar) round-trip exactly.  Each save
is atomic (tmp + rename).  For multi-host production this layer would shard
per process; on this single-host container it writes one file per step.
"""

from __future__ import annotations

import json
import os
import re
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Tree = Any
_STEP_RE = re.compile(r"step_(\d+)\.npz$")


def _flatten(tree: Tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(ckpt_dir: str, step: int, tree: Tree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    dtypes = {}
    arrays = {}
    for k, v in flat.items():
        if v.dtype == jnp.bfloat16:
            arrays[k] = v.view(np.uint16)
            dtypes[k] = "bfloat16"
        else:
            arrays[k] = v
            dtypes[k] = str(v.dtype)
    path = os.path.join(ckpt_dir, f"step_{step}.npz")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, __dtypes__=json.dumps(dtypes), **arrays)
    os.replace(tmp, path)
    return path


def restore_checkpoint(ckpt_dir: str, step: int, like: Tree) -> Tree:
    """Restore into the structure of ``like`` (shapes/dtypes validated)."""
    path = os.path.join(ckpt_dir, f"step_{step}.npz")
    with np.load(path, allow_pickle=False) as data:
        dtypes = json.loads(str(data["__dtypes__"]))
        flat = {}
        for k in data.files:
            if k == "__dtypes__":
                continue
            arr = data[k]
            if dtypes[k] == "bfloat16":
                arr = arr.view(jnp.bfloat16)
            flat[k] = arr

    ref = _flatten(like)
    if set(ref) != set(flat):
        missing = set(ref) ^ set(flat)
        raise ValueError(f"checkpoint/tree key mismatch: {sorted(missing)[:5]}")
    leaves_ref, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path_k, leaf in leaves_ref:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path_k)
        arr = flat[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        out.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := _STEP_RE.search(f))]
    return max(steps) if steps else None
