"""Pure-jnp oracle for the INT4 SpGEMV kernel: dequantize then einsum."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import QuantizedTensor, dequantize_int4


def spgemv_scores_ref(
    q: jax.Array,  # (B, group, d)
    packed: jax.Array,  # (B, n, d//2) uint8
    scale: jax.Array,  # (B, n)
    zero: jax.Array,  # (B, n)
    *,
    sm_scale: float,
) -> jax.Array:
    qt = QuantizedTensor(packed=packed, scale=scale[..., None], zero=zero[..., None])
    k = dequantize_int4(qt)  # (B, n, d)
    return jnp.einsum("bgd,bnd->bgn", q.astype(jnp.float32), k) * sm_scale
