"""Serving launcher: batched decode with the Twilight engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --requests 8 --prompt-len 96 --max-new 16

``--paged`` switches to the persistent continuous-batching engine over the
shared page pool; ``--mixed`` generates a ragged workload (varied prompt
lengths and per-request max_new_tokens) — the regime where continuous
batching beats wave batching.  ``--prefix-share`` additionally turns on
copy-on-write prefix caching with chunked prefill (attention-only stacks),
and ``--shared-prefix-len N`` makes every request open with the same
N-token prefix — the regime where sharing pays.  ``--calls N`` splits the
workload into N successive ``generate()`` calls against ONE engine: the
paged engine is a persistent session, so calls 2..N hit the radix tree
populated by call 1 (per-call hit telemetry is printed).  ``--selector``
overrides the Twilight selector — ``h2o`` now runs paged, backed by the
pool's per-physical-page accumulated attention mass.  ``--fused``
overrides ``TwilightConfig.fused_backend`` — ``fused`` runs the whole
estimate/top-p/attend tail as one Pallas launch per layer per decode
step.  ``--page-top-p P`` turns on the hierarchical page→token nucleus: the
selector keeps the smallest set of candidate pages reaching page-score
mass P before the token-level top-p prunes inside them.
``--prefill-top-p P`` applies the same page nucleus to the *prefill*
path: each query block attends only the pages whose Quest upper-bound
scores reach mass P (1.0 is the dense-oracle mode, bit-exact vs flash).
``--run-stats`` collects survivor-run telemetry (contiguous-run
histogram, pages touched per step, and — under ``--page-top-p`` — the
live-candidate-pages histogram; under ``--prefill-top-p`` — live vs
candidate prefill pages) and prints the session summary;
``--decode-window K`` lets the paged engine decode up to K queued
tokens per slot in one fused launch (speeds preemption replay).
``--compare`` runs
both schedulers on the same workload and reports both tok/s figures (with
``--prefix-share``: share-on vs share-off paged engines).
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.serving import DecodeEngine, Request


def _build_requests(cfg, args, rng) -> list[Request]:
    reqs = []
    shared = (rng.integers(8, cfg.vocab_size, args.shared_prefix_len
                           ).astype(np.int32)
              if args.shared_prefix_len else None)
    for uid in range(args.requests):
        extras = {}
        if cfg.frontend == "audio":
            extras["frames"] = rng.normal(
                size=(args.prompt_len, cfg.d_model)).astype(np.float32)
        elif cfg.frontend == "vision":
            extras["patches"] = rng.normal(
                size=(cfg.n_prefix_tokens, cfg.d_model)).astype(np.float32)
        if args.mixed:
            prompt_len = int(rng.integers(max(8, args.prompt_len // 4),
                                          args.prompt_len + 1))
            max_new = int(rng.integers(max(1, args.max_new // 4),
                                       args.max_new + 1))
        else:
            prompt_len, max_new = args.prompt_len, args.max_new
        prompt = rng.integers(8, cfg.vocab_size, prompt_len).astype(np.int32)
        if shared is not None:
            prompt = np.concatenate([shared, prompt])
        reqs.append(Request(
            uid=uid,
            prompt=prompt,
            max_new_tokens=max_new,
            extras=extras or None,
        ))
    return reqs


def _run(cfg, args, reqs, *, paged: bool, prefix_share: bool = False,
         params=None) -> float:
    engine = DecodeEngine(cfg, params=params, batch_size=args.batch,
                          cache_capacity=args.capacity, seed=args.seed,
                          paged=paged, num_pages=args.pages,
                          prefix_share=prefix_share,
                          decode_window=(args.decode_window if paged else 1))
    n_calls = max(1, args.calls) if paged else 1
    per_call = -(-len(reqs) // n_calls)
    t0 = time.time()
    results = []
    for c in range(n_calls):
        chunk = reqs[c * per_call:(c + 1) * per_call]
        if not chunk:
            break
        results.extend(engine.generate(chunk))
        if prefix_share and n_calls > 1:
            print(f"[serve]   call {c}: {len(chunk)} requests, "
                  f"{engine.last_prefix_hits} prefix hits, "
                  f"{engine.last_prefix_tokens} tokens reused")
    wall = time.time() - t0
    total_tokens = sum(r.decode_steps for r in results)
    budgets = [r.mean_pruned_budget for r in results]
    mode = ("continuous/paged+prefix-share" if prefix_share
            else "continuous/paged" if paged else "wave/contiguous")
    if n_calls > 1:
        mode += f", persistent x{n_calls} calls"
    print(f"[serve] {cfg.name} ({mode}): {len(results)} requests, "
          f"{total_tokens} tokens in {wall:.1f}s "
          f"({total_tokens / wall:.1f} tok/s CPU-interpret)")
    print(f"[serve] mean Twilight pruned budget: {np.mean(budgets):.1f} "
          f"tokens (capacity {args.capacity})")
    if prefix_share:
        print(f"[serve] prefix cache (session): "
              f"{engine.session_prefix_hits} hits, "
              f"{engine.session_prefix_tokens} prompt tokens reused, "
              f"{engine.session_cow_copies} COW copies, "
              f"{engine.session_evictions} evictions, "
              f"{engine.session_prefill_chunks} prefill chunks")
    if paged:
        print(f"[serve] session: {engine.session_submitted} submitted, "
              f"{engine.session_completed} completed, "
              f"{engine.session_preemptions} preemptions")
        rs = engine.session_run_stats()
        if rs is not None:
            print(f"[serve] survivor runs: {rs['runs_per_step']:.1f} runs/"
                  f"step (mean len {rs['mean_run_len']:.1f}), "
                  f"{rs['pages_per_step']:.1f} pages/step, "
                  f"{rs['kept_per_step']:.1f} kept rows/step over "
                  f"{rs['steps']} steps")
            print(f"[serve] run-length histogram (log2 buckets 1,2-3,4-7,"
                  f"...): {rs['run_hist']}")
            if rs["cand_rows_per_step"] > 0:
                print(f"[serve] page nucleus: "
                      f"{rs['cand_pages_per_step']:.1f} live candidate "
                      f"pages/step, {rs['cand_rows_per_step']:.1f} live "
                      f"slots/step; live-pages histogram (log2): "
                      f"{rs['live_page_hist']}")
            if rs["prefill_qblocks"] > 0:
                print(f"[serve] sparse prefill: "
                      f"{rs['prefill_pages_live']:.0f} of "
                      f"{rs['prefill_pages_cand']:.0f} candidate pages "
                      f"attended ({100 * rs['prefill_live_frac']:.1f}%) "
                      f"across {rs['prefill_qblocks']:.0f} query blocks")
    return total_tokens / wall


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--capacity", type=int, default=256)
    ap.add_argument("--paged", action="store_true",
                    help="continuous batching over the shared page pool")
    ap.add_argument("--pages", type=int, default=None,
                    help="page-pool size (default: worst case + null page)")
    ap.add_argument("--mixed", action="store_true",
                    help="ragged workload: varied prompt/max-new per request")
    ap.add_argument("--prefix-share", action="store_true",
                    help="COW prefix caching + chunked prefill "
                         "(implies --paged; attention-only stacks)")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="prepend the same N-token prefix to every request")
    ap.add_argument("--calls", type=int, default=1,
                    help="split the workload into N successive generate() "
                         "calls against one persistent engine (paged only)")
    ap.add_argument("--selector", default=None,
                    help="override the Twilight selector (e.g. h2o — now "
                         "paged-capable via per-page accumulated mass)")
    ap.add_argument("--fused", default=None,
                    choices=["auto", "fused", "staged"],
                    help="decode-attention backend: 'fused' runs estimate/"
                         "top-p/attend as one Pallas launch per layer "
                         "(kernels/fused_decode), 'staged' keeps the "
                         "three-launch compact pipeline, 'auto' (default) "
                         "fuses on TPU only")
    ap.add_argument("--compare", action="store_true",
                    help="run both schedulers on the same workload "
                         "(with --prefix-share: share-on vs share-off)")
    ap.add_argument("--run-stats", action="store_true",
                    help="collect survivor-run telemetry per decode step "
                         "(contiguous-run histogram, pages touched, live "
                         "candidate pages) and print the session summary "
                         "(paged only)")
    ap.add_argument("--page-top-p", type=float, default=None,
                    help="hierarchical page nucleus: keep the smallest set "
                         "of candidate pages whose softmaxed page scores "
                         "reach this mass before the token-level top-p "
                         "(1.0 = keep all, identical to the flat pipeline)")
    ap.add_argument("--prefill-top-p", type=float, default=None,
                    help="hierarchical top-p sparse prefill: per query "
                         "block, attend only the smallest set of pages "
                         "whose Quest upper-bound scores reach this mass "
                         "(1.0 = dense-oracle mode, bit-exact vs flash)")
    ap.add_argument("--decode-window", type=int, default=1,
                    help="decode up to K queued tokens per slot per fused "
                         "launch (paged, attention-only stacks; >1 "
                         "accelerates preemption replay)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if (args.selector or args.fused or args.run_stats
            or args.page_top_p is not None
            or args.prefill_top_p is not None):
        import dataclasses
        tw = cfg.twilight
        if args.selector:
            tw = dataclasses.replace(tw, selector=args.selector)
        if args.fused:
            tw = dataclasses.replace(tw, fused_backend=args.fused)
        if args.run_stats:
            tw = dataclasses.replace(tw, collect_run_stats=True)
        if args.page_top_p is not None:
            tw = dataclasses.replace(tw, page_top_p=args.page_top_p)
        if args.prefill_top_p is not None:
            tw = dataclasses.replace(tw, prefill_top_p=args.prefill_top_p)
        cfg = cfg.replace(twilight=tw)
    rng = np.random.default_rng(args.seed)
    reqs = _build_requests(cfg, args, rng)

    if args.compare:
        from repro.models import init_params
        import jax
        params = init_params(cfg, jax.random.PRNGKey(args.seed))
        if args.prefix_share:
            base = _run(cfg, args, reqs, paged=True, params=params)
            shared = _run(cfg, args, reqs, paged=True, prefix_share=True,
                          params=params)
            print(f"[serve] prefix-share vs paged: "
                  f"{shared / base:.2f}x tok/s")
        else:
            wave = _run(cfg, args, reqs, paged=False, params=params)
            cont = _run(cfg, args, reqs, paged=True, params=params)
            print(f"[serve] continuous vs wave: {cont / wave:.2f}x tok/s")
    else:
        _run(cfg, args, reqs, paged=args.paged or args.prefix_share,
             prefix_share=args.prefix_share)


if __name__ == "__main__":
    main()
