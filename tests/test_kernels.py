"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import QuantizedTensor
from repro.kernels.quant.kernel import quantize_int4_rows
from repro.kernels.quant.ops import quantize_cache
from repro.kernels.quant.ref import quantize_int4_rows_ref
from repro.kernels.sparse_attn.kernel import sparse_decode_attention
from repro.kernels.sparse_attn.ops import gathered_attention, masked_attention
from repro.kernels.sparse_attn.ref import sparse_decode_attention_ref
from repro.kernels.spgemv.kernel import spgemv_scores
from repro.kernels.spgemv.ops import estimate_scores
from repro.kernels.spgemv.ref import spgemv_scores_ref
from repro.kernels.topp.kernel import topp_threshold_rows
from repro.kernels.topp.ops import topp_mask as topp_mask_kernel
from repro.kernels.topp.ref import topp_budget_oracle, topp_threshold_rows_ref
from repro.core.topp import topp_mask as topp_mask_core
from tests.conftest import make_weights


# ---------------------------------------------------------------------------
# quant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rows,d", [(8, 32), (96, 128), (256, 64), (33, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_kernel_matches_ref(rng, rows, d, dtype):
    x = jnp.asarray(rng.normal(size=(rows, d)), dtype)
    pk, sk, zk = quantize_int4_rows(x, interpret=True)
    pr, sr, zr = quantize_int4_rows_ref(x)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(zk), np.asarray(zr), rtol=1e-6)
    if dtype == jnp.float32:
        np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    else:
        # bf16 inputs can land exactly on a rounding tie; codes may differ
        # by 1 on a handful of elements.  Dequantized values must agree to
        # within one quantization step either way.
        low_k = (np.asarray(pk) & 0xF).astype(np.int32)
        low_r = (np.asarray(pr) & 0xF).astype(np.int32)
        hi_k = (np.asarray(pk) >> 4).astype(np.int32)
        hi_r = (np.asarray(pr) >> 4).astype(np.int32)
        assert np.abs(low_k - low_r).max() <= 1
        assert np.abs(hi_k - hi_r).max() <= 1
        frac = ((low_k != low_r) | (hi_k != hi_r)).mean()
        assert frac < 0.01, f"too many tie flips: {frac}"


def test_quant_cache_wrapper(rng):
    K = jnp.asarray(rng.normal(size=(2, 64, 4, 32)), jnp.float32)
    qt = quantize_cache(K, interpret=True)
    assert qt.packed.shape == (2, 64, 4, 16)
    from repro.core.quant import dequantize_int4
    err = np.abs(np.asarray(dequantize_int4(qt)) - np.asarray(K))
    assert (err <= np.asarray(qt.scale) / 2 + 1e-6).all()


# ---------------------------------------------------------------------------
# spgemv
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,block_n", [(256, 64), (512, 512), (384, 128)])
@pytest.mark.parametrize("group,d", [(1, 64), (4, 128)])
def test_spgemv_kernel_matches_ref(rng, n, block_n, group, d):
    B = 3
    q = jnp.asarray(rng.normal(size=(B, group, d)), jnp.float32)
    K = jnp.asarray(rng.normal(size=(B * n, d)), jnp.float32)
    pk, sk, zk = quantize_int4_rows(K, interpret=True)
    packed = pk.reshape(B, n, d // 2)
    scale = sk.reshape(B, n)
    zero = zk.reshape(B, n)
    out = spgemv_scores(q[..., 0::2], q[..., 1::2], packed, scale, zero,
                        sm_scale=d ** -0.5, block_n=block_n, interpret=True)
    ref = spgemv_scores_ref(q, packed, scale, zero, sm_scale=d ** -0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_estimate_scores_matches_pruner_path(rng):
    """Kernel wrapper == TwilightPruner.estimate_scores (same INT4 cache)."""
    from repro.core.pruner import TwilightPruner
    b, hq, hkv, n, d = 2, 8, 2, 128, 64
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    K = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    qt = quantize_cache(K, interpret=True)
    kernel_scores = estimate_scores(q, qt, interpret=True)
    ref_scores = TwilightPruner(estimate_bits=4).estimate_scores(
        q, None, qt)
    # The jnp pruner fallback dequantizes to bf16 (memory; see pruner.py)
    # while the kernel folds exact f32 dequant into the matmul — allow the
    # bf16 rounding of the reference.
    np.testing.assert_allclose(np.asarray(kernel_scores),
                               np.asarray(ref_scores), rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# topp
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [64, 257, 1024, 4096])
@pytest.mark.parametrize("p", [0.5, 0.9, 0.99])
def test_topp_kernel_matches_ref_and_oracle(rng, n, p):
    w = jnp.asarray(make_weights(rng, 16, n, 3.0))
    tk, bk = topp_threshold_rows(w, jnp.float32(p), interpret=True)
    tr, br = topp_threshold_rows_ref(w, jnp.float32(p))
    np.testing.assert_allclose(np.asarray(tk), np.asarray(tr), atol=1e-6)
    # Summation order differs between the kernel's block reduction and the
    # reference; near-tie thresholds may shift the budget by a token or two
    # in the dense tail (p=0.99 on large n).  Semantics are checked by the
    # coverage assertion below.
    assert np.abs(np.asarray(bk) - np.asarray(br)).max() <= max(2, n // 512)
    bo = topp_budget_oracle(w, p)
    assert np.abs(np.asarray(bk) - np.asarray(bo)).max() <= max(2, n // 512)
    kept = np.where(np.asarray(w) >= np.asarray(tk), np.asarray(w), 0).sum(-1)
    assert (kept >= p - 1e-5).all(), "kernel threshold must still cover p"


def test_topp_kernel_wrapper_matches_core(rng):
    w = jnp.asarray(make_weights(rng, 12, 300, 4.0)).reshape(3, 4, 300)
    rk = topp_mask_kernel(w, 0.9, interpret=True)
    rc = topp_mask_core(w, 0.9)
    np.testing.assert_array_equal(np.asarray(rk.mask), np.asarray(rc.mask))


# ---------------------------------------------------------------------------
# sparse_attn
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,block_n", [(256, 128), (384, 128), (512, 512)])
@pytest.mark.parametrize("group,d", [(1, 64), (4, 128)])
@pytest.mark.parametrize("density", [0.02, 0.3, 1.0])
def test_sparse_attn_kernel_matches_ref(rng, n, block_n, group, d, density):
    B = 3
    q = jnp.asarray(rng.normal(size=(B, group, d)), jnp.float32)
    K = jnp.asarray(rng.normal(size=(B, n, d)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(B, n, d)), jnp.float32)
    mask = jnp.asarray(rng.random((B, n)) < density)
    mask = mask.at[:, 0].set(True)  # avoid fully-empty rows
    out = sparse_decode_attention(q, K, V, mask, sm_scale=d ** -0.5,
                                  block_n=block_n, interpret=True)
    ref = sparse_decode_attention_ref(q, K, V, mask, sm_scale=d ** -0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_sparse_attn_empty_row_is_zero(rng):
    q = jnp.asarray(rng.normal(size=(1, 2, 32)), jnp.float32)
    K = jnp.asarray(rng.normal(size=(1, 64, 32)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(1, 64, 32)), jnp.float32)
    mask = jnp.zeros((1, 64), bool)
    out = sparse_decode_attention(q, K, V, mask, sm_scale=1.0, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_masked_vs_gathered_equivalence(rng):
    """Engine fast path: gather-then-attend == mask-then-attend."""
    from repro.core.attention import masked_sparse_decode_attention
    b, hq, hkv, n, d, m = 2, 4, 2, 128, 64, 32
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    K = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    idx = np.stack([np.stack([rng.choice(n, m, replace=False)
                              for _ in range(hkv)]) for _ in range(b)])
    mask = np.zeros((b, hkv, n), bool)
    for i in range(b):
        for h in range(hkv):
            mask[i, h, idx[i, h]] = True
    out_g = gathered_attention(q, K, V, jnp.asarray(idx),
                               jnp.ones((b, hkv, m), bool), interpret=True)
    out_m = masked_sparse_decode_attention(q, K, V, jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_m),
                               rtol=2e-5, atol=2e-5)


def test_masked_attention_wrapper_bf16(rng):
    b, hq, hkv, n, d = 2, 4, 2, 128, 64
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.bfloat16)
    K = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.bfloat16)
    V = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.bfloat16)
    mask = jnp.asarray(rng.random((b, hkv, n)) < 0.2)
    out = masked_attention(q, K, V, mask, interpret=True)
    assert out.dtype == jnp.bfloat16
    assert np.isfinite(np.asarray(out, np.float32)).all()
