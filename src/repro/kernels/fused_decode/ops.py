"""Public wrapper: fused estimate→top-p→attend over a candidate buffer.

Adapts the model/cache layout — q (b, hq, d), candidate indices
(b, hkv, m), K/V as either the per-slot contiguous cache (b, n, hkv, d) or
the shared page pool (P, hkv, d) — to the kernel's (B = b*hkv, ...) layout.
The INT4 codes are gathered at the candidate indices first (same XLA
gather the staged estimate performs — every candidate's code is read by
definition); the fp16 K/V stay in HBM and only *surviving* rows are DMA'd
inside the kernel.

``fused_vmem_bytes``/``fused_fits`` size the per-grid-step VMEM working
set; the pipeline falls back to the staged path when a candidate buffer
would not fit (only enforced on real TPUs — interpret mode has no VMEM).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import QuantizedTensor
from repro.kernels.common import resolve_interpret
from repro.kernels.fused_decode.kernel import fused_decode_rows

# Per-core VMEM is ~16 MB; leave headroom for the compiler's own buffers.
FUSED_VMEM_BUDGET = 12 << 20


def fused_vmem_bytes(m: int, d: int, group: int, kv_bytes: int = 2) -> int:
    """Analytic VMEM working set of one (slot, kv-head) grid step.

    Codes block (m × (d/2 + 8 + 1 + 4 + 1)): packed nibbles, f32
    scale/zero, valid bitmap, i32 rows; ~3 live (group, m) f32 score/weight
    rows; queries and the two (1, 1, d) DMA scratch rows.
    """
    codes = m * (d // 2 + 8 + 1 + 4 + 1)
    score_rows = 3 * group * m * 4
    small = 3 * group * d * 4 + 2 * d * kv_bytes
    return codes + score_rows + small


def fused_fits(m: int, d: int, group: int, kv_bytes: int = 2) -> bool:
    """Static go/no-go for the fused kernel at this candidate capacity."""
    if resolve_interpret(None):
        return True  # interpret mode has no VMEM ceiling
    return fused_vmem_bytes(m, d, group, kv_bytes) <= FUSED_VMEM_BUDGET


def fused_prune_attend(
    q: jax.Array,  # (b, hq, d)
    indices: jax.Array,  # (b, hkv, m) i32 — cache rows (physical if paged)
    valid: jax.Array,  # (b, hkv, m) bool — live candidate slots
    keys: jax.Array,  # (b, n, hkv, d) cache or (P, hkv, d) pool
    values: jax.Array,  # same layout as keys
    qkeys: QuantizedTensor | None = None,  # INT4 shadow, same layout
    *,
    p: jax.Array | float,
    iters: int = 24,
    sm_scale: float | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Single-launch prune + attend.

    Returns ``(out (b, hq, d), kept (b, hkv, m) bool, slot_weights
    (b, hkv, m) f32, threshold (b, hq) f32)`` — exactly the pieces the
    compact pipeline otherwise assembles from three kernel launches.
    ``kept`` is the GQA group union; every kept slot is attended (the
    staged path with ``pruned_cap_frac=None``).
    """
    from repro.core.attention import gather_quantized_kv_heads

    b, hq, d = q.shape
    hkv, m = indices.shape[1], indices.shape[2]
    group = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)

    # Same staging (and same gather-vs-quantize bit-identity) as the
    # staged estimate — one definition in repro.core.attention.
    gathered = gather_quantized_kv_heads(indices, keys=keys, qkeys=qkeys)

    qg = q.reshape(b, hkv, group, d).reshape(b * hkv, group, d)
    out, kept, slot_w, thresh = fused_decode_rows(
        qg, qg[..., 0::2], qg[..., 1::2],
        gathered.packed.reshape(b * hkv, m, d // 2),
        gathered.scale[..., 0].reshape(b * hkv, m).astype(jnp.float32),
        gathered.zero[..., 0].reshape(b * hkv, m).astype(jnp.float32),
        valid.reshape(b * hkv, m),
        indices.reshape(b * hkv, m),
        jnp.asarray(p, jnp.float32),
        keys, values,
        sm_scale=float(sm_scale), iters=iters, hkv=hkv,
        pooled=keys.ndim == 3, interpret=interpret,
    )
    return (out.reshape(b, hq, d),
            kept.reshape(b, hkv, m) != 0,
            slot_w.reshape(b, hkv, m),
            thresh.reshape(b, hq))
