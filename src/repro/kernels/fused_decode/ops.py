"""Public wrapper: fused estimate→top-p→attend over a candidate buffer.

Adapts the model/cache layout — q (b, [kw,] hq, d), candidate indices
(b, hkv, m), K/V as either the per-slot contiguous cache (b, n, hkv, d) or
the shared page pool (P, hkv, d) — to the kernel's (B = b*hkv, ...) layout.
The INT4 codes are gathered at the candidate indices first (same XLA
gather the staged estimate performs — every candidate's code is read by
definition); the fp16 K/V stay in HBM and only *surviving* rows are
streamed, block-run by block-run, inside the kernel.

``fused_prune_attend_window`` is the primary entry: one launch prunes and
attends ``kw`` window positions per slot against one shared candidate
buffer (selection anchored once, per-position causal validity in
``valid``).  ``fused_prune_attend`` is the kw = 1 special case and keeps
its original signature.

``fused_vmem_bytes``/``fused_fits`` size the per-grid-step VMEM working
set — including the doubled K/V staging buffers and the k-token
score/accumulator rows — so the "auto" backend falls back to the staged
path *before* a real VMEM overflow (only enforced on real TPUs —
interpret mode has no VMEM ceiling unless ``interpret=False`` is forced).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import QuantizedTensor
from repro.kernels.common import resolve_interpret
from repro.kernels.fused_decode.kernel import (
    coalesce_block,
    fused_decode_rows,
)

# Per-core VMEM is ~16 MB; leave headroom for the compiler's own buffers.
FUSED_VMEM_BUDGET = 12 << 20


def fused_vmem_bytes(m: int, d: int, group: int, kv_bytes: int = 2, *,
                     k: int = 1, page_size: int = 64) -> int:
    """Analytic VMEM working set of one (slot, kv-head) grid step.

    Terms, in kernel order: the codes block (packed nibbles + f32
    scale/zero + i32 rows); per-position valid/kept bitmaps and the f32
    group-max weight rows (×k); ~3 live (k·group, m) f32 score/weight
    rows; the whole + nibble-split queries; the k-token online-softmax
    accumulator (m/l/acc per query row); the int8 page-survivor mask
    (m / blk blocks — carried unconditionally so the budget is one
    number for both stage-1 modes); and the double-buffered K and V
    block staging scratch (2 buffers × 2 streams × blk rows).
    """
    blk = coalesce_block(m, page_size)
    kg = k * group
    codes = m * (d // 2 + 8 + 4)
    per_pos = k * m * (1 + 1 + 4)
    score_rows = 3 * kg * m * 4
    queries = 3 * kg * d * 4
    accum = kg * (d + 2) * 4
    page_mask = m // blk
    staging = 2 * 2 * blk * d * kv_bytes
    return codes + per_pos + score_rows + queries + accum + page_mask \
        + staging


def fused_fits(m: int, d: int, group: int, kv_bytes: int = 2, *,
               k: int = 1, page_size: int = 64,
               interpret: bool | None = None) -> bool:
    """Static go/no-go for the fused kernel at this candidate capacity.

    ``interpret=False`` forces the real budget check (interpret mode has
    no VMEM ceiling, so the default tri-state always fits off-TPU).
    """
    if resolve_interpret(interpret):
        return True
    return fused_vmem_bytes(m, d, group, kv_bytes, k=k,
                            page_size=page_size) <= FUSED_VMEM_BUDGET


def fused_prune_attend_window(
    q: jax.Array,  # (b, kw, hq, d) — kw queued window positions per slot
    indices: jax.Array,  # (b, hkv, m) i32 — cache rows (physical if paged)
    valid: jax.Array,  # (b, kw, hkv, m) bool — per-position live slots
    keys: jax.Array,  # (b, n, hkv, d) cache or (P, hkv, d) pool
    values: jax.Array,  # same layout as keys
    qkeys: QuantizedTensor | None = None,  # INT4 shadow, same layout
    *,
    p: jax.Array | float,
    iters: int = 24,
    sm_scale: float | None = None,
    page_size: int = 64,
    hierarchical: bool = False,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Single-launch multi-token prune + attend.

    All kw positions share ONE candidate buffer (selection anchored once
    upstream); per-position causal masking arrives through ``valid``.
    The kernel streams the *window union* of per-position survivor sets
    from HBM once and runs kw online-softmax accumulations against it.

    ``hierarchical=True`` tells the kernel the candidate buffer carries an
    adaptive page-nucleus survivor set (whole pages of slots may be dead):
    stage 1 walks blk-aligned blocks and early-outs dead pages instead of
    running one flat matmul, so estimate compute tracks the live count.

    Returns ``(out (b, kw, hq, d), kept (b, kw, hkv, m) bool,
    slot_weights (b, kw, hkv, m) f32, threshold (b, kw, hq) f32)``.
    ``kept`` is the per-position GQA group union; every kept slot is
    attended by that position (the staged path with
    ``pruned_cap_frac=None``).
    """
    from repro.core.attention import gather_quantized_kv_heads

    b, kw, hq, d = q.shape
    hkv, m = indices.shape[1], indices.shape[2]
    group = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)

    # Same staging (and same gather-vs-quantize bit-identity) as the
    # staged estimate — one definition in repro.core.attention.
    gathered = gather_quantized_kv_heads(indices, keys=keys, qkeys=qkeys)

    # kv-head-major query rows: row r = j * group + g inside each head.
    qg = q.reshape(b, kw, hkv, group, d).transpose(0, 2, 1, 3, 4)
    qg = qg.reshape(b * hkv, kw * group, d)
    vg = valid.transpose(0, 2, 1, 3).reshape(b * hkv, kw, m)
    out, kept, slot_w, thresh = fused_decode_rows(
        qg, qg[..., 0::2], qg[..., 1::2],
        gathered.packed.reshape(b * hkv, m, d // 2),
        gathered.scale[..., 0].reshape(b * hkv, m).astype(jnp.float32),
        gathered.zero[..., 0].reshape(b * hkv, m).astype(jnp.float32),
        vg,
        indices.reshape(b * hkv, m),
        jnp.asarray(p, jnp.float32),
        keys, values,
        sm_scale=float(sm_scale), iters=iters, hkv=hkv,
        pooled=keys.ndim == 3, page_size=page_size,
        hierarchical=hierarchical, interpret=interpret,
    )
    out = out.reshape(b, hkv, kw, group, d).transpose(0, 2, 1, 3, 4)
    thresh = thresh.reshape(b, hkv, kw, group).transpose(0, 2, 1, 3)
    return (out.reshape(b, kw, hq, d),
            kept.reshape(b, hkv, kw, m).transpose(0, 2, 1, 3) != 0,
            slot_w.reshape(b, hkv, kw, m).transpose(0, 2, 1, 3),
            thresh.reshape(b, kw, hq))


def fused_prune_attend(
    q: jax.Array,  # (b, hq, d)
    indices: jax.Array,  # (b, hkv, m) i32 — cache rows (physical if paged)
    valid: jax.Array,  # (b, hkv, m) bool — live candidate slots
    keys: jax.Array,  # (b, n, hkv, d) cache or (P, hkv, d) pool
    values: jax.Array,  # same layout as keys
    qkeys: QuantizedTensor | None = None,  # INT4 shadow, same layout
    *,
    p: jax.Array | float,
    iters: int = 24,
    sm_scale: float | None = None,
    page_size: int = 64,
    hierarchical: bool = False,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Single-launch prune + attend (the kw = 1 window special case).

    Returns ``(out (b, hq, d), kept (b, hkv, m) bool, slot_weights
    (b, hkv, m) f32, threshold (b, hq) f32)`` — exactly the pieces the
    compact pipeline otherwise assembles from three kernel launches.
    """
    out, kept, slot_w, thresh = fused_prune_attend_window(
        q[:, None], indices, valid[:, None], keys, values, qkeys,
        p=p, iters=iters, sm_scale=sm_scale, page_size=page_size,
        hierarchical=hierarchical, interpret=interpret)
    return out[:, 0], kept[:, 0], slot_w[:, 0], thresh[:, 0]
