"""Public jit'd wrapper for the INT4 quantization kernel.

Accepts the cache layout (b, n, hkv, d) and returns a
``repro.core.quant.QuantizedTensor`` with the same leading shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import QuantizedTensor
from repro.kernels.quant.kernel import quantize_int4_rows


def quantize_cache(
    keys: jax.Array,  # (b, n, hkv, d)
    *,
    block_rows: int = 256,
    interpret: bool | None = None,
) -> QuantizedTensor:
    b, n, hkv, d = keys.shape
    rows = keys.reshape(b * n * hkv, d)
    packed, scale, zero = quantize_int4_rows(
        rows, block_rows=block_rows, interpret=interpret
    )
    return QuantizedTensor(
        packed=packed.reshape(b, n, hkv, d // 2),
        scale=scale.reshape(b, n, hkv, 1),
        zero=zero.reshape(b, n, hkv, 1),
    )
