"""Pallas kernel: per-row asymmetric INT4 quantization + nibble packing.

Rows are (token, head) pairs; the quantized axis is the head dim ``d``.
Packing matches ``repro.core.quant``: even channel -> low nibble, odd
channel -> high nibble of byte ``d//2`` (Appendix B.1 interleaved layout).

TPU notes: the row block lives in VMEM; min/max/round/clip are VPU ops and
the nibble merge is an integer shift+or.  ``block_rows`` should be a
multiple of 8 (f32 sublane) and ``d`` a multiple of 256 packs to a
128-lane-aligned uint8 tile; d=128 (the common head dim) packs to 64 lanes,
which Mosaic handles via lane folding.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import resolve_interpret

_LEVELS = 15.0


def _quant_kernel(x_ref, packed_ref, scale_ref, zero_ref):
    x = x_ref[...].astype(jnp.float32)  # (block_rows, d)
    lo = jnp.min(x, axis=-1, keepdims=True)
    hi = jnp.max(x, axis=-1, keepdims=True)
    scale = jnp.maximum((hi - lo) / _LEVELS, 1e-8)
    codes = jnp.clip(jnp.round((x - lo) / scale), 0.0, _LEVELS).astype(jnp.uint8)
    r, d = codes.shape
    pairs = codes.reshape(r, d // 2, 2)
    packed_ref[...] = pairs[..., 0] | (pairs[..., 1] << 4)
    scale_ref[...] = scale
    zero_ref[...] = lo


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def quantize_int4_rows(
    x: jax.Array,  # (rows, d), d even
    *,
    block_rows: int = 256,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    interpret = resolve_interpret(interpret)
    rows, d = x.shape
    block_rows = min(block_rows, rows)
    if rows % block_rows:
        # Fall back to a divisor block; rows is caller-padded in the engine.
        while rows % block_rows:
            block_rows -= 1
    grid = (rows // block_rows,)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, d // 2), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, d // 2), jnp.uint8),
            jax.ShapeDtypeStruct((rows, 1), jnp.float32),
            jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
