"""Jamba-1.5-Large 398B [arXiv:2403.19887] — hybrid Mamba:attention 1:7
interleave, MoE 16 experts top-2 on every other layer."""

from repro.core.twilight import TwilightConfig
from repro.models.common import ArchType, MoEConfig, ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        arch_type=ArchType.HYBRID,
        n_layers=72,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=24576,
        vocab_size=65536,
        attn_period=8,  # 1 attention per 8 layers (1:7)
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
        moe=MoEConfig(n_experts=16, top_k=2, n_shared=0, d_expert=24576,
                      period=2, dense_d_ff=24576),
        twilight=TwilightConfig(selector="quest", p=0.95),
        citation="arXiv:2403.19887",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512,
        attn_period=4,
        ssm=SSMConfig(d_state=4, d_conv=2, expand=2),
        moe=MoEConfig(n_experts=4, top_k=2, n_shared=0, d_expert=128,
                      period=2, dense_d_ff=128),
        twilight=TwilightConfig(selector="quest", p=0.9, page_size=8,
                                min_candidate=16),
    )
