"""Analytic cost model vs ground truth (eval_shape param counts)."""

import functools

import jax
import numpy as np
import pytest

from repro.analysis.costs import (
    active_param_count,
    collective_bytes_per_chip,
    decode_flops,
    decode_hbm_bytes,
    forward_flops,
    param_count_estimate,
    train_step_flops,
)
from repro.configs import ARCH_IDS, get_config
from repro.models import init_params


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_estimate_matches_eval_shape(arch):
    cfg = get_config(arch)
    struct = jax.eval_shape(functools.partial(init_params, cfg),
                            jax.random.PRNGKey(0))
    actual = sum(int(np.prod(x.shape))
                 for x in jax.tree_util.tree_leaves(struct))
    est = param_count_estimate(cfg)
    rel = abs(est - actual) / actual
    assert rel < 0.02, f"{arch}: estimate {est:,} vs actual {actual:,} ({rel:.3f})"


def test_active_less_than_total_for_moe():
    cfg = get_config("deepseek-moe-16b")
    assert active_param_count(cfg) < 0.3 * param_count_estimate(cfg)
    dense = get_config("qwen2-1.5b")
    assert active_param_count(dense) == param_count_estimate(dense)


def test_flops_scale_with_tokens():
    cfg = get_config("qwen2-1.5b")
    f1 = forward_flops(cfg, 1, 1024)
    f2 = forward_flops(cfg, 2, 1024)
    assert 1.9 < f2 / f1 < 2.2  # ~linear in batch (attention superlinear in s)
    assert train_step_flops(cfg, 1, 1024) == 3 * f1


def test_forward_flops_close_to_6nd():
    """Dense fwd ≈ 2·N·D when context << d_model regime doesn't dominate."""
    cfg = get_config("starcoder2-15b")
    tokens = 4096 * 16
    f = forward_flops(cfg, 16, 4096)
    two_nd = 2 * param_count_estimate(cfg) * tokens
    assert 0.8 < f / two_nd < 1.5, f / two_nd


def test_decode_twilight_cheaper_than_full():
    import dataclasses
    cfg = get_config("qwen3-32b")
    full_cfg = cfg.replace(twilight=dataclasses.replace(
        cfg.twilight, enabled=False))
    assert decode_flops(cfg, 128, 32768) < decode_flops(full_cfg, 128, 32768)
    assert decode_hbm_bytes(cfg, 128, 32768) < \
        decode_hbm_bytes(full_cfg, 128, 32768)
    # The paper's whole point: the traffic gap grows with context.
    r32 = decode_hbm_bytes(full_cfg, 128, 32768) / decode_hbm_bytes(cfg, 128, 32768)
    assert r32 > 2.0, r32


def test_collective_model_terms():
    cfg = get_config("qwen2-1.5b")
    train = collective_bytes_per_chip(cfg, "train", 256, 4096)
    decode = collective_bytes_per_chip(cfg, "decode", 128, 32768)
    assert train["total"] > 100 * decode["total"]
    assert train["seq_parallel"] > 0  # SP active for dense train
    assert decode["seq_parallel"] == 0
