"""Activation-sharding constraints, settable per launch context.

The model code is sharding-agnostic; the launcher installs logical-axis
rules here and the model calls :func:`constrain` at block boundaries.
Without installed rules (unit tests, single-device runs) it is a no-op.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def _rules() -> dict[str, P] | None:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def activation_rules(rules: dict[str, P]):
    """rules: logical name -> PartitionSpec, e.g. {"residual": P(("data",), None, None)}."""
    prev = _rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def constrain(x: Any, name: str) -> Any:
    rules = _rules()
    if rules is None or name not in rules:
        return x
    spec = rules[name]
    if not isinstance(spec, P):
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def get_value(name: str, default: Any = None) -> Any:
    """Non-spec launch hints (e.g. 'moe_shards': local-dispatch shard count)."""
    rules = _rules()
    if rules is None:
        return default
    return rules.get(name, default)
