"""InternVL2-1B [arXiv:2404.16821] — Qwen2-0.5B-family language decoder
consuming InternViT patch embeddings (vision encoder stubbed; the LM sees
a 256-token patch-embedding prefix from `input_specs()`)."""

from repro.core.twilight import TwilightConfig
from repro.models.common import ArchType, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b",
        arch_type=ArchType.VLM,
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        d_ff=4864,
        vocab_size=151655,
        qkv_bias=True,
        rope_theta=1e6,
        tie_embeddings=True,
        frontend="vision",
        n_prefix_tokens=256,
        twilight=TwilightConfig(selector="quest", p=0.95),
        citation="arXiv:2404.16821",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512, n_prefix_tokens=16,
        twilight=TwilightConfig(selector="quest", p=0.9, page_size=8,
                                min_candidate=16),
    )
