"""End-to-end driver: train a ~100M-param dense GQA model for a few hundred
steps on the synthetic Zipf-Markov corpus, checkpoint it, then decode with
Twilight sparse attention and compare against full attention.

Defaults are sized for this CPU container (~10 minutes); pass --full100m to
train the actual 100M config (slower).

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--full100m]
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_smoke_config
from repro.core import TwilightConfig
from repro.data import DataConfig, synthetic_lm_batches, zipf_markov_tokens
from repro.models import count_params, decode_step, init_params, prefill
from repro.training import TrainConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=192)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--full100m", action="store_true")
    ap.add_argument("--ckpt-dir", default="results/example_lm")
    args = ap.parse_args()

    cfg = get_smoke_config("qwen2-1.5b")
    if args.full100m:
        cfg = cfg.replace(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                          d_ff=2048, vocab_size=32768)
    params = init_params(cfg, jax.random.PRNGKey(0))
    print(f"[example] {count_params(params):,} params")

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, seed=0)
    tcfg = TrainConfig(peak_lr=3e-3, warmup_steps=args.steps // 10,
                       total_steps=args.steps, remat=False)
    params, hist = train_loop(params, cfg, tcfg,
                              synthetic_lm_batches(dcfg, args.steps))
    save_checkpoint(args.ckpt_dir, args.steps, params)
    print(f"[example] loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}; "
          f"checkpoint in {args.ckpt_dir}")

    # Decode-time comparison: full attention vs Twilight.
    rng = np.random.default_rng(9)
    toks = jnp.asarray(zipf_markov_tokens(dcfg, rng, 4)[:, :args.seq])

    def decode_nll(cfg_v):
        dec = jax.jit(lambda p, st, t: decode_step(p, cfg_v, st, t))
        _, state = jax.jit(lambda p, tk: prefill(p, cfg_v, {"tokens": tk},
                                                 args.seq))(params,
                                                            toks[:, :64])
        nll, budgets = 0.0, []
        for t in range(64, args.seq - 1):
            logits, state, stats = dec(params, state, toks[:, t])
            lp = jax.nn.log_softmax(
                logits[:, :cfg.vocab_size].astype(jnp.float32))
            nll -= float(jnp.take_along_axis(
                lp, toks[:, t + 1][:, None], -1).mean())
            budgets.append(float(stats["mean_pruned_budget"]))
        return np.exp(nll / (args.seq - 65)), np.mean(budgets)

    ppl_full, _ = decode_nll(cfg.replace(twilight=TwilightConfig(enabled=False)))
    ppl_twi, budget = decode_nll(cfg.replace(twilight=dataclasses.replace(
        cfg.twilight, p=0.95, candidate_frac=0.5)))
    print(f"[example] decode ppl: full={ppl_full:.3f}  "
          f"twilight={ppl_twi:.3f} (mean budget {budget:.0f}/{args.seq})")


if __name__ == "__main__":
    main()
