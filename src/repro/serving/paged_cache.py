"""Paged KV-cache pool: ref-counted block allocator + pool array helpers.

The serving engine provisions ONE shared pool of ``num_pages`` fixed-size
pages per attention layer instead of a contiguous ``(batch, capacity)``
cache per slot.  Each request owns only the pages its tokens actually fill
(prefill allocates ceil(len/page_size); decode allocates one page at each
page boundary), so memory scales with live tokens, not with
``batch * worst_case`` — the substrate that makes continuous batching pay.

Pages are **ref-counted** so one physical page can back many readers:

* ``alloc`` hands out pages at refcount 1 (the caller's reference);
* ``share`` takes an extra reference — the prefix cache
  (:mod:`repro.serving.prefix_cache`) shares every page it indexes, and a
  request that matches a cached prefix shares those pages instead of
  re-prefilling them;
* ``free`` *decrements*; the page returns to the free list only when the
  last reference drops.  Retiring a request therefore never yanks a page
  out from under the prefix cache or another live reader — and preemption
  is decrement-only, so a victim's shared prefix stays resident.
* ``cow`` implements copy-on-write: writing to a page with refcount > 1
  must first ``cow`` it, which allocates a private replacement (the caller
  copies the device rows with ``models.model.copy_page``) and drops the
  shared reference.  A page with refcount 1 is returned unchanged — the
  caller already owns it exclusively.

Layout (per attention layer, see ``models.model._attn_pool_init``):

* ``k``/``v``:            (num_pages * page_size, hkv, d) token rows
* ``qk_packed/scale/zero``: INT4 shadow cache, same token-row layout
* ``pmax``/``pmin``:      (num_pages, hkv, d) Quest metadata per *physical*
  page — selectors gather it through the per-slot page table
* ``h2o_mass``:           (num_pages, hkv) accumulated attention mass per
  *physical* page (H2O serving state; ``selector == "h2o"`` only).  The
  decode step scatter-adds the pruner's post-top-p weights; pages are
  zeroed when written fresh so recycling never leaks a previous occupant's
  signal, ``copy_page`` carries the row across a COW, and shared prefix
  pages pool every reader's mass.
* ``ds_channels``:        (batch, hkv, r) per-slot Double-Sparsity label
  channels, calibrated on each slot's own prompt
* page table:             (batch, max_pages) i32, engine-managed **host**
  state mirrored to device as plain data each step

Physical page 0 is the **null page**: never allocated, the scatter target
for dead slots and the safe-gather target for invalid index-buffer slots.
All allocation bookkeeping is host-side Python (a free list + a refcount
map); device state never stores pointers, only the page-table array — so
the jitted decode step stays a pure function of arrays and the allocator
needs no tracing.
"""

from __future__ import annotations

__all__ = ["NULL_PAGE", "PageAllocator", "pages_for", "pad_to_pages"]

NULL_PAGE = 0


def pages_for(n_tokens: int, page_size: int) -> int:
    """Number of pages needed to hold ``n_tokens`` token rows."""
    return -(-max(0, n_tokens) // page_size)


def pad_to_pages(n_tokens: int, page_size: int) -> int:
    """``n_tokens`` rounded up to a whole number of pages."""
    return pages_for(n_tokens, page_size) * page_size


class PageAllocator:
    """Ref-counted free-list allocator over physical page ids ``1..num_pages-1``.

    Page 0 (:data:`NULL_PAGE`) is reserved.  Pages are recycled LIFO so a
    steady-state workload keeps touching the same hot pages.  Invariants
    (asserted, and exercised by ``tests/test_paged_cache.py`` and the
    property tests in ``tests/test_prefix_cache.py``):

    * a page is never handed out twice while any reference is live
    * ``free`` of an unreferenced (or null) page raises
    * ``available + len(allocated) == num_pages - 1`` at all times
    * ``share``/``free`` conserve references: a page returns to the free
      list exactly when its refcount reaches 0
    """

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError("need at least one allocatable page + the null page")
        self.num_pages = num_pages
        self._free: list[int] = list(range(num_pages - 1, NULL_PAGE, -1))
        self._ref: dict[int, int] = {}

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def capacity(self) -> int:
        """Total allocatable pages (excludes the null page)."""
        return self.num_pages - 1

    @property
    def allocated(self) -> frozenset[int]:
        return frozenset(self._ref)

    def refcount(self, page: int) -> int:
        """Live references on ``page`` (0 if unallocated)."""
        return self._ref.get(page, 0)

    def alloc(self, n: int) -> list[int]:
        """Pop ``n`` pages off the free list; raises MemoryError if short."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} pages")
        if n > len(self._free):
            raise MemoryError(
                f"page pool exhausted: want {n}, have {len(self._free)}")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        return pages

    def share(self, pages: list[int]) -> None:
        """Take one extra reference on each page (must be allocated)."""
        for p in pages:
            if p not in self._ref:
                raise ValueError(f"cannot share unallocated page {p}")
            self._ref[p] += 1

    def free(self, pages: list[int]) -> None:
        """Drop one reference per page; recycle pages that reach 0."""
        for p in pages:
            if p == NULL_PAGE:
                raise ValueError("cannot free the null page")
            if p not in self._ref:
                raise ValueError(f"double free of page {p}")
            self._ref[p] -= 1
            if self._ref[p] == 0:
                del self._ref[p]
                self._free.append(p)

    def cow(self, page: int) -> tuple[int, bool]:
        """Copy-on-write resolve for a page the caller wants to *write*.

        Returns ``(writable_page, copied)``.  With refcount 1 the caller
        already owns the page exclusively — returned unchanged, no copy.
        With refcount > 1 a fresh page is allocated (raises MemoryError if
        the pool is dry), the caller's reference on the shared page is
        dropped, and ``copied=True`` signals that the device rows must be
        duplicated (``models.model.copy_page``) before writing.
        """
        if self.refcount(page) < 1:
            raise ValueError(f"cannot cow unallocated page {page}")
        if self._ref[page] == 1:
            return page, False
        new = self.alloc(1)[0]
        self.free([page])
        return new, True
