from repro.serving.engine import DecodeEngine, GenerationResult, Request
from repro.serving.sampler import sample_token, top_p_sample

__all__ = ["DecodeEngine", "GenerationResult", "Request", "sample_token",
           "top_p_sample"]
