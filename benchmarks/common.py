"""Shared benchmark utilities: tiny trained models (cached), decode-time
PPL / retrieval evaluation under arbitrary Twilight configs, timing, and
the TPU-v5e analytic latency model used for the efficiency tables.

This container is CPU-only, so operator *speedups* are reported from the
memory-traffic cost model (decode attention is memory-bound — the paper's
own premise); accuracy numbers are measured for real on models trained
here, and algorithm microbenchmarks (top-p search etc.) are wall-clock.
"""

from __future__ import annotations

import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_smoke_config
from repro.core import TwilightConfig
from repro.data import DataConfig, needle_batch, synthetic_lm_batches
from repro.models import decode_step, init_params, prefill
from repro.training import TrainConfig, train_loop

CACHE_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                         "bench_cache")

# TPU v5e hardware model (per chip) — see repro.launch.mesh.
HBM_BW = 819e9
PEAK_FLOPS = 197e12


def bench_config(vocab=512, layers=4):
    """The tiny LM all accuracy benches share (dense GQA, qwen2 family)."""
    cfg = get_smoke_config("qwen2-1.5b")
    return cfg.replace(
        n_layers=layers, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=vocab,
        twilight=TwilightConfig(selector="quest", p=0.9, page_size=8,
                                min_candidate=16),
    )


def _train(cfg, data_iter, steps, tag, lr=3e-3):
    os.makedirs(CACHE_DIR, exist_ok=True)
    ckpt_dir = os.path.join(CACHE_DIR, tag)
    params = init_params(cfg, jax.random.PRNGKey(0))
    step = latest_step(ckpt_dir)
    if step == steps:
        return restore_checkpoint(ckpt_dir, steps, params)
    tcfg = TrainConfig(peak_lr=lr, warmup_steps=max(1, steps // 10),
                       total_steps=steps, remat=False)
    params, _ = train_loop(params, cfg, tcfg, data_iter, log_every=steps)
    save_checkpoint(ckpt_dir, steps, params)
    return params


def lm_model(steps=300, seq=192, batch=16):
    """Tiny LM trained on the Zipf-Markov corpus (PG-19 stand-in)."""
    cfg = bench_config()
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                      global_batch=batch, seed=0)
    params = _train(cfg, synthetic_lm_batches(dcfg, steps), steps, "lm")
    return cfg, params


def needle_model(steps=800, seq=160, batch=16):
    """Tiny LM trained on the needle-retrieval task (RULER stand-in).

    Training sequences end with (QUERY_MARK, key) and the loss supervises
    ONLY the answer token — the model must form the induction circuit
    (attend back to the needle site) to score; the filler is uniform noise
    and carries no gradient (labels = -1)."""
    cfg = bench_config()
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                      global_batch=batch, seed=1)
    rng = np.random.default_rng(1)

    def batches():
        for i in range(steps):
            nb = needle_batch(dcfg, rng, batch)
            inputs = nb["tokens"]  # ends with (QUERY_MARK, key)
            labels = np.full_like(inputs, -1)
            labels[:, -1] = nb["answers"]  # predict the value after the key
            yield {"tokens": inputs, "labels": labels}

    params = _train(cfg, batches(), steps, "needle", lr=3e-3)
    return cfg, params


# ---------------------------------------------------------------------------
# Decode-time evaluation under a Twilight config
# ---------------------------------------------------------------------------

def eval_decode_ppl(params, cfg, tokens: np.ndarray, *, warm: int = 32,
                    capacity: int | None = None):
    """Teacher-forced decode PPL + mean pruned budget.

    tokens: (b, s).  The first ``warm`` tokens prefill; the rest decode one
    by one through the full Twilight pipeline (this is what makes sparse
    attention affect the score).
    """
    b, s = tokens.shape
    capacity = capacity or s
    toks = jnp.asarray(tokens)
    dec = jax.jit(lambda p, st, t: decode_step(p, cfg, st, t))
    _, state = jax.jit(lambda p, tk: prefill(p, cfg, {"tokens": tk}, capacity)
                       )(params, toks[:, :warm])
    nll, count, budgets = 0.0, 0, []
    for t in range(warm, s - 1):
        logits, state, stats = dec(params, state, toks[:, t])
        logp = jax.nn.log_softmax(logits[:, :cfg.vocab_size].astype(jnp.float32))
        nll -= float(jnp.take_along_axis(
            logp, toks[:, t + 1][:, None], axis=-1).mean())
        count += 1
        budgets.append(float(stats["mean_pruned_budget"]))
    return float(np.exp(nll / max(count, 1))), float(np.mean(budgets))


def eval_needle_acc(params, cfg, batch: dict, *, capacity: int | None = None):
    """Retrieval accuracy: the token decoded after the query must be the
    planted value."""
    toks = jnp.asarray(batch["tokens"])
    b, s = toks.shape
    capacity = capacity or s
    _, state = jax.jit(lambda p, tk: prefill(p, cfg, {"tokens": tk}, capacity)
                       )(params, toks[:, :s - 1])
    logits, state, stats = jax.jit(
        lambda p, st, t: decode_step(p, cfg, st, t))(params, state,
                                                     toks[:, s - 1])
    pred = np.asarray(jnp.argmax(logits[:, :cfg.vocab_size], axis=-1))
    acc = float((pred == batch["answers"]).mean())
    return acc, float(stats["mean_pruned_budget"])


def twilight_variant(cfg, **kw):
    return cfg.replace(twilight=dataclasses.replace(cfg.twilight, **kw))


# ---------------------------------------------------------------------------
# Analytic decode-attention latency model (paper §4.3 adapted to v5e)
# ---------------------------------------------------------------------------

def attn_bytes_full(n, hkv, d, bytes_kv=2):
    """Full attention: read all of K and V."""
    return 2 * n * hkv * d * bytes_kv


def attn_bytes_quest(n, hkv, d, b0, page=64, bytes_kv=2):
    """Quest: page metadata (2 vectors/page) + selected K,V."""
    meta = 2 * (n // page) * hkv * d * bytes_kv
    return meta + 2 * b0 * hkv * d * bytes_kv


def attn_bytes_quest_twi(n, hkv, d, b0, b1, page=64, bytes_kv=2):
    """Quest+Twilight: metadata + INT4 estimate over B0 + final K,V over B1
    + the top-p pass over B0 weights (f32)."""
    meta = 2 * (n // page) * hkv * d * bytes_kv
    est = b0 * hkv * (d // 2 + 8)  # packed nibbles + scale/zero
    topp = 4 * b0 * hkv
    final = 2 * b1 * hkv * d * bytes_kv
    return meta + est + topp + final


def bytes_to_us(nbytes, batch=1):
    return batch * nbytes / HBM_BW * 1e6


def timed(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6, out


def csv_row(name: str, us: float, derived: str):
    print(f"{name},{us:.2f},{derived}")
