"""Serving engine: wave-batched (contiguous) and continuous (paged) decode.

Two scheduling modes around the same model:

* ``paged=False`` — the legacy wave scheduler: fixed batch slots, every
  request in a wave decodes for the wave's ``max(max_new_tokens)`` against a
  per-slot contiguous cache of ``cache_capacity`` tokens.  Kept as the
  equivalence oracle (same role as ``TwilightConfig.compact=False``).
* ``paged=True`` — **true continuous batching** over a shared page pool
  (``repro.serving.paged_cache``): slots retire and admit new requests at
  every decode step; each request owns only the KV pages its tokens fill
  (prefill allocates ceil(len/page_size), decode allocates one page per
  boundary crossing, retirement frees them).  Per-request
  ``max_new_tokens``, ragged prompt lengths, and per-slot sampling modes
  are all data; the jitted step is compiled once per
  (batch, num_pages, max_pages) and reused.

The decode loop stays async in both modes: sampling runs inside the jitted
step, per-step token/budget frames stay on device, and the host fetches
them ONCE after the queue drains.  Host-side work per step is pure
bookkeeping (page allocation, admission, retirement) on numpy mirrors of
the page table — never a device sync.

When the pool runs dry mid-decode the engine preempts the most recently
admitted victim by *restart*: its pages are freed and the request is
requeued at the front, to be re-served from its prompt.  For greedy
requests the regenerated tokens are identical (asserted in
``tests/test_paged_cache.py``); sampled requests draw a fresh
continuation.  (True vLLM-style recompute — one prefill over
prompt+generated — would need the victim's device-side token frames
synced to the host mid-loop; left as a follow-up.)  Admission keeps one
boundary-page of headroom per live slot to make preemption rare.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (
    decode_step,
    decode_step_paged,
    init_paged_decode_state,
    init_params,
    prefill,
    write_prefill_slot,
)
from repro.models.common import ModelConfig
from repro.serving.paged_cache import PageAllocator, pad_to_pages, pages_for
from repro.serving.sampler import sample_token

Tree = Any


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (s,) int32
    max_new_tokens: int = 32
    greedy: bool = True
    extras: dict | None = None  # modality-frontend embeddings


@dataclasses.dataclass
class GenerationResult:
    uid: int
    tokens: list[int]
    prompt_len: int
    decode_steps: int
    mean_pruned_budget: float
    wall_s: float


@dataclasses.dataclass
class _SlotRun:
    """Host bookkeeping for one admitted request."""

    req: Request
    slot: int
    pages: list[int]
    tok0: jax.Array  # () device scalar — sampled from the prefill logits
    start_frame: int  # first decode frame this slot participates in
    emitted: int  # tokens sampled so far (tok0 included)
    t_admit: float
    order: int  # admission sequence number (preemption picks the newest)


class DecodeEngine:
    """Batched decode engine around (prefill, decode_step[_paged])."""

    def __init__(self, cfg: ModelConfig, params: Tree | None = None, *,
                 batch_size: int = 8, cache_capacity: int = 512, seed: int = 0,
                 paged: bool = False, num_pages: int | None = None):
        tw = cfg.twilight
        if tw.enabled and tw.compact and tw.pruned_cap_frac is None:
            # Serving default: B1-scaled final gather (ROADMAP follow-up).
            # The attended buffer is re-compacted to 1/4 of the candidate
            # buffer, far above the paper's measured ~2 %-of-n budgets.
            cfg = cfg.replace(
                twilight=dataclasses.replace(tw, pruned_cap_frac=0.25))
        self.cfg = cfg
        self.batch_size = batch_size
        self.cache_capacity = cache_capacity
        self.paged = paged
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else init_params(cfg, key)
        self._sample_key = jax.random.PRNGKey(seed + 1)

        self._prefill = jax.jit(
            lambda p, batch: prefill(p, cfg, batch, cache_capacity))
        self._decode = jax.jit(lambda p, st, tok: decode_step(p, cfg, st, tok))

        if paged:
            tw = cfg.twilight
            if not (tw.enabled and tw.compact):
                raise ValueError("paged serving requires the compact "
                                 "Twilight pipeline")
            ps = tw.page_size
            if cache_capacity % ps:
                raise ValueError(f"cache_capacity {cache_capacity} not "
                                 f"divisible by page_size {ps}")
            self.max_pages = cache_capacity // ps
            # Default pool: worst case (every slot full) + the null page —
            # no smaller than wave mode, but callers shrink it to realize
            # the memory win (utilization tracks live tokens, not slots).
            self.num_pages = (num_pages if num_pages is not None
                              else 1 + batch_size * self.max_pages)
            prefix = (cfg.n_prefix_tokens if cfg.frontend == "vision" else 0)
            self._prefill_paged = jax.jit(lambda p, batch: prefill(
                p, cfg, batch,
                pad_to_pages(batch["tokens"].shape[1] + prefix, ps)))
            self._write = jax.jit(
                lambda st, pst, slot, pages: write_prefill_slot(
                    cfg, st, pst, slot, pages),
                donate_argnums=(0,))

            def _step_fn(p, state, tok, pt, lengths, live, greedy, key):
                logits, state, stats = decode_step_paged(
                    p, cfg, state, tok, pt, lengths, live)
                nxt = sample_token(key, logits[:, :cfg.vocab_size],
                                   greedy=greedy)
                return nxt, state, stats["pruned_budget"]

            self._step = jax.jit(_step_fn, donate_argnums=(1,))

    # -- dispatch -----------------------------------------------------------

    def generate(self, requests: list[Request]) -> list[GenerationResult]:
        """Serve requests: continuous batching when paged, else waves."""
        if self.paged:
            return self._serve_continuous(requests)
        results: list[GenerationResult] = []
        queue = list(requests)
        while queue:
            wave = queue[:self.batch_size]
            queue = queue[self.batch_size:]
            results.extend(self._serve_wave(wave))
        return results

    # -- wave mode (the contiguous-cache oracle) ----------------------------

    def _serve_wave(self, wave: list[Request]) -> list[GenerationResult]:
        t0 = time.time()
        b = len(wave)
        s = max(len(r.prompt) for r in wave)
        s = min(s, self.cache_capacity - max(r.max_new_tokens for r in wave))
        toks = np.zeros((b, s), np.int32)
        for i, r in enumerate(wave):
            pr = r.prompt[-s:]
            toks[i, -len(pr):] = pr  # left-pad with token 0
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.frontend == "audio":
            frames = np.stack([r.extras["frames"] for r in wave])
            batch["frames"] = jnp.asarray(frames)
        elif self.cfg.frontend == "vision":
            patches = np.stack([r.extras["patches"] for r in wave])
            batch["patches"] = jnp.asarray(patches)

        logits, state = self._prefill(self.params, batch)
        last = logits[:, -1, :self.cfg.vocab_size]  # drop padded vocab rows
        max_new = max(r.max_new_tokens for r in wave)
        # Per-slot sampling mode: a greedy and a sampling request can share
        # a wave (previously collapsed to all(r.greedy)).  A uniform wave
        # keeps the Python-bool fast path (argmax only — no wasted
        # softmax/top-p work for the common all-greedy case).
        modes = [r.greedy for r in wave]
        greedy = modes[0] if len(set(modes)) == 1 else jnp.asarray(modes)
        # The decode loop stays async: tokens and the budget accumulator
        # live on device and are fetched ONCE per wave.  A float()/asarray()
        # inside the loop would block on the device every token and
        # serialize dispatch against compute.
        out_toks_dev = []
        budget_sum = jnp.zeros((), jnp.float32)
        for step in range(max_new):
            self._sample_key, k = jax.random.split(self._sample_key)
            tok = sample_token(k, last, greedy=greedy)
            out_toks_dev.append(tok)
            last, state, stats = self._decode(self.params, state, tok)
            last = last[:, :self.cfg.vocab_size]
            budget_sum = budget_sum + stats["mean_pruned_budget"]

        out_tokens = (np.stack([np.asarray(t) for t in out_toks_dev], axis=1)
                      if out_toks_dev else np.zeros((b, 0), np.int32))
        mean_budget = float(budget_sum) / max_new if max_new else 0.0
        wall = time.time() - t0
        results = []
        for i, r in enumerate(wave):
            results.append(GenerationResult(
                uid=r.uid,
                tokens=out_tokens[i, :r.max_new_tokens].tolist(),
                prompt_len=len(r.prompt),
                decode_steps=r.max_new_tokens,
                mean_pruned_budget=mean_budget,
                wall_s=wall,
            ))
        return results

    # -- continuous mode (paged pool) ---------------------------------------

    def _batch_one(self, req: Request, prompt: np.ndarray) -> dict:
        batch = {"tokens": jnp.asarray(prompt[None])}
        if self.cfg.frontend == "audio":
            batch["frames"] = jnp.asarray(req.extras["frames"][None])
        elif self.cfg.frontend == "vision":
            batch["patches"] = jnp.asarray(req.extras["patches"][None])
        return batch

    def _sample_one(self, logits_row: jax.Array, greedy: bool) -> jax.Array:
        self._sample_key, k = jax.random.split(self._sample_key)
        return sample_token(k, logits_row[None], greedy=greedy)[0]

    def _serve_continuous(self, requests: list[Request]
                          ) -> list[GenerationResult]:
        self.last_preemptions = 0  # telemetry: recompute preemptions
        if not requests:
            return []
        cfg = self.cfg
        ps = cfg.twilight.page_size
        prefix = cfg.n_prefix_tokens if cfg.frontend == "vision" else 0
        b = self.batch_size
        n_enc = 0
        if cfg.frontend == "audio":
            n_enc = len(requests[0].extras["frames"])
            if any(len(r.extras["frames"]) != n_enc for r in requests):
                raise ValueError("audio requests must share a frame length")

        alloc = PageAllocator(self.num_pages)
        state = init_paged_decode_state(cfg, b, self.num_pages, n_enc=n_enc)
        pt = np.zeros((b, self.max_pages), np.int32)
        lengths = np.zeros((b,), np.int32)
        live = np.zeros((b,), bool)
        greedy = np.ones((b,), bool)
        slots: list[_SlotRun | None] = [None] * b
        pending: deque[Request] = deque(requests)
        cur_tok = jnp.zeros((b,), jnp.int32)
        tok_frames: list[jax.Array] = []  # (b,) per step, stay on device
        budget_frames: list[jax.Array] = []
        done: list[tuple[_SlotRun, float]] = []  # (run, retire time)
        order = 0

        def admit(slot: int) -> bool:
            nonlocal state, cur_tok, order
            req = pending[0]
            prompt = np.asarray(req.prompt, np.int32)
            cap = self.cache_capacity - prefix
            if req.max_new_tokens >= cap:
                raise ValueError(
                    f"request {req.uid}: max_new_tokens "
                    f"{req.max_new_tokens} cannot fit cache_capacity "
                    f"{self.cache_capacity} (prefix {prefix})")
            keep = cap - req.max_new_tokens  # >= 1
            if len(prompt) > keep:
                prompt = prompt[-keep:]
            s_total = len(prompt) + prefix
            worst = pages_for(s_total + req.max_new_tokens, ps)
            if worst > alloc.capacity:
                raise ValueError(
                    f"request {req.uid} needs {worst} pages; pool has "
                    f"{alloc.capacity} — raise num_pages")
            n_req = pages_for(s_total, ps)
            live_count = sum(1 for r in slots if r is not None)
            # Alone, a request is admitted only if its worst case fits (it
            # then completes without preemption — no livelock); alongside
            # live slots, keep one boundary page of headroom per slot.
            need = worst if live_count == 0 else n_req + live_count
            if alloc.available < need:
                return False
            pending.popleft()
            pages = alloc.alloc(n_req)
            logits, pstate = self._prefill_paged(
                self.params, self._batch_one(req, prompt))
            state = self._write(state, pstate, jnp.int32(slot),
                                jnp.asarray(pages, jnp.int32))
            tok0 = self._sample_one(logits[0, s_total - 1, :cfg.vocab_size],
                                    req.greedy)
            run = _SlotRun(req=req, slot=slot, pages=pages, tok0=tok0,
                           start_frame=len(tok_frames), emitted=1,
                           t_admit=time.time(), order=order)
            order += 1
            if req.max_new_tokens <= 1:
                alloc.free(pages)
                done.append((run, time.time()))
                return True
            slots[slot] = run
            pt[slot, :n_req] = pages
            pt[slot, n_req:] = 0
            lengths[slot] = s_total
            live[slot] = True
            greedy[slot] = req.greedy
            cur_tok = cur_tok.at[slot].set(tok0)
            return True

        def retire(slot: int, preempted: bool = False) -> None:
            run = slots[slot]
            alloc.free(run.pages)
            slots[slot] = None
            live[slot] = False
            pt[slot] = 0
            lengths[slot] = 0
            if preempted:
                pending.appendleft(run.req)
            else:
                done.append((run, time.time()))

        def preempt_for_page(needy: int) -> None:
            victims = [r for r in (slots[s] for s in range(b))
                       if r is not None and r.slot != needy]
            victim = (max(victims, key=lambda r: r.order).slot
                      if victims else needy)
            self.last_preemptions += 1
            retire(victim, preempted=True)

        while pending or any(live):
            # Admission: fill every free slot while the queue and pool allow
            # (an instantly-retired max_new=1 request frees its slot again).
            slot = 0
            while pending and slot < b:
                if slots[slot] is None:
                    if not admit(slot):
                        break
                    if slots[slot] is None:
                        continue
                slot += 1
            if not any(live):
                if pending:
                    # Nothing live to retire yet the head request stalls:
                    # only possible transiently after mass preemption; loop.
                    continue
                break
            # Boundary pages for this step's appends.
            for slot in range(b):
                if live[slot] and lengths[slot] % ps == 0:
                    while alloc.available < 1:
                        preempt_for_page(slot)
                    if not live[slot]:  # self-preempted (last resort)
                        continue
                    page = alloc.alloc(1)[0]
                    slots[slot].pages.append(page)
                    pt[slot, lengths[slot] // ps] = page
            if not any(live):
                continue
            # One jitted step for the whole batch; dead slots compute junk
            # into the null page.
            self._sample_key, k = jax.random.split(self._sample_key)
            cur_tok, state, budget = self._step(
                self.params, state, cur_tok, jnp.asarray(pt),
                jnp.asarray(lengths), jnp.asarray(live), jnp.asarray(greedy),
                k)
            tok_frames.append(cur_tok)
            budget_frames.append(budget)
            for slot in range(b):
                if not live[slot]:
                    continue
                lengths[slot] += 1
                run = slots[slot]
                run.emitted += 1
                if run.emitted >= run.req.max_new_tokens:
                    retire(slot)

        # Single host sync: fetch every decode frame at once.
        toks = (np.stack([np.asarray(t) for t in tok_frames])
                if tok_frames else np.zeros((0, b), np.int32))
        buds = (np.stack([np.asarray(x) for x in budget_frames])
                if budget_frames else np.zeros((0, b), np.float32))
        results = []
        for run, t_done in done:
            n_dec = run.req.max_new_tokens - 1
            frames = toks[run.start_frame:run.start_frame + n_dec, run.slot]
            frame_buds = buds[run.start_frame:run.start_frame + n_dec,
                              run.slot]
            results.append(GenerationResult(
                uid=run.req.uid,
                tokens=[int(np.asarray(run.tok0))] + frames.tolist(),
                prompt_len=len(run.req.prompt),
                decode_steps=run.req.max_new_tokens,
                mean_pruned_budget=(float(frame_buds.mean())
                                    if len(frame_buds) else 0.0),
                wall_s=t_done - run.t_admit,
            ))
        return results
