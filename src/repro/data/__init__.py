from repro.data.pipeline import (
    DataConfig,
    batch_for_arch,
    needle_batch,
    synthetic_lm_batches,
    zipf_markov_tokens,
)

__all__ = [
    "DataConfig",
    "batch_for_arch",
    "needle_batch",
    "synthetic_lm_batches",
    "zipf_markov_tokens",
]
