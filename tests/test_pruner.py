"""Twilight Pruner + error-bound validation (Eq. 2 of the paper)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    PrunerStats,
    SelectionContext,
    TwilightConfig,
    TwilightPruner,
    attention_error,
    build_page_meta,
    calibrate_ds_channels,
    full_decode_attention,
    masked_sparse_decode_attention,
    twilight_decode_attention,
)


def _setup(rng, b=2, hq=8, hkv=2, n=512, d=64, focused=True):
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    K = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    if focused:
        # Plant keys aligned with queries so attention peaks hard.
        qk = np.asarray(q).reshape(b, hkv, hq // hkv, d).mean(2)
        Kn = np.array(K)
        for i in range(b):
            for h in range(hkv):
                Kn[i, 17 + 11 * h, h] = 4.0 * qk[i, h]
        K = jnp.asarray(Kn)
    V = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    return q, K, V


@pytest.mark.parametrize("p", [0.8, 0.9, 0.95])
def test_error_bound(rng, p):
    """‖o − ô‖ ≤ (1 − kept_mass)·‖V‖_F with exact weights; with INT4
    estimation the kept mass is computed from estimated weights, so allow
    the quantization slack on top."""
    q, K, V = _setup(rng)
    pruner = TwilightPruner(p=p, estimate_bits=16)  # exact weights
    cand = jnp.ones((2, 2, 512), bool)
    mask, stats = pruner.prune(q, cand, keys=K)
    o_exact = full_decode_attention(q, K, V)
    o_sparse = masked_sparse_decode_attention(q, K, V, mask)
    err = np.asarray(attention_error(o_exact, o_sparse))
    v_norm = float(jnp.linalg.norm(V[0, :, 0]))
    # Kept mass >= p by construction -> bound (1-p)*||V||_F.
    # Renormalized sparse attention only tightens it.
    assert (err <= (1 - p) * v_norm + 1e-3).all(), (err.max(), (1 - p) * v_norm)


def test_int4_estimation_close_to_exact(rng):
    q, K, V = _setup(rng)
    cand = jnp.ones((2, 2, 512), bool)
    m16, s16 = TwilightPruner(p=0.9, estimate_bits=16).prune(q, cand, keys=K)
    m4, s4 = TwilightPruner(p=0.9, estimate_bits=4).prune(q, cand, keys=K)
    # Kept-mass of the INT4 selection measured under EXACT weights (Fig. 6).
    w_exact = np.asarray(s16.weights)
    mask4_q = np.repeat(np.asarray(m4), 4, axis=1)
    kept = np.where(mask4_q, w_exact, 0).sum(-1)
    assert (kept > 0.8).all(), f"INT4 selection lost too much mass: {kept.min()}"


def test_pruner_respects_candidates(rng):
    q, K, V = _setup(rng)
    cand = jnp.zeros((2, 2, 512), bool).at[:, :, :128].set(True)
    mask, _ = TwilightPruner(p=0.95).prune(q, cand, keys=K)
    assert not np.asarray(mask)[:, :, 128:].any()


def test_focused_prunes_harder_than_diffuse(rng):
    qf, Kf, Vf = _setup(rng, focused=True)
    qd = jnp.asarray(rng.normal(size=(2, 8, 64)) * 0.05, jnp.float32)
    cand = jnp.ones((2, 2, 512), bool)
    bf = TwilightPruner(p=0.9).prune(qf, cand, keys=Kf)[1].pruned_budget
    bd = TwilightPruner(p=0.9).prune(qd, cand, keys=Kf)[1].pruned_budget
    assert float(bf.mean()) < float(bd.mean())


def test_full_pipeline_all_selectors(rng):
    q, K, V = _setup(rng)
    pm = build_page_meta(K, 16)
    ctx = SelectionContext(keys=K, page_meta=pm,
                           accum_scores=jnp.asarray(
                               rng.random((2, 2, 512)), jnp.float32),
                           length=None,
                           ds_channels=calibrate_ds_channels(K, 8))
    o_exact = full_decode_attention(q, K, V)
    v_norm = float(jnp.linalg.norm(V[0, :, 0]))
    for sel in ("full", "quest", "double_sparsity", "streaming", "h2o"):
        cfg = TwilightConfig(selector=sel, p=0.9, candidate_frac=0.5,
                             page_size=16, min_candidate=64)
        out = twilight_decode_attention(q, K, V, cfg, ctx=ctx)
        err = float(attention_error(o_exact, out.out).max())
        assert np.isfinite(np.asarray(out.out)).all()
        # Selector candidates may miss mass; full selector must meet the bound.
        if sel == "full":
            assert err <= 0.1 * v_norm + 1e-3


def test_disabled_equals_full(rng):
    q, K, V = _setup(rng)
    cfg = TwilightConfig(enabled=False)
    out = twilight_decode_attention(q, K, V, cfg)
    exact = full_decode_attention(q, K, V)
    np.testing.assert_allclose(np.asarray(out.out), np.asarray(exact),
                               rtol=1e-5, atol=1e-5)


def test_prune_disabled_pure_topk(rng):
    """prune_enabled=False == the base top-k algorithm alone."""
    import dataclasses
    q, K, V = _setup(rng)
    cfg = TwilightConfig(selector="quest", prune_enabled=False,
                         fixed_budget=128, page_size=16)
    # Budgets equal the fixed candidate budget (no pruning happened) — in
    # both the dense-mask and the compact-index representation.
    dense = twilight_decode_attention(
        q, K, V, dataclasses.replace(cfg, compact=False))
    np.testing.assert_array_equal(np.asarray(dense.pruned_mask),
                                  np.asarray(dense.candidate_mask))
    comp = twilight_decode_attention(q, K, V, cfg)
    np.testing.assert_array_equal(np.asarray(comp.pruned_valid),
                                  np.asarray(comp.candidate_valid))


def test_gqa_budgets_are_group_wise(rng):
    q, K, V = _setup(rng, hq=8, hkv=2)
    cand = jnp.ones((2, 2, 512), bool)
    mask, stats = TwilightPruner(p=0.9).prune(q, cand, keys=K)
    assert mask.shape == (2, 2, 512)  # kv-head granular
    # Union can only grow the per-head budget.
    assert (np.asarray(stats.pruned_budget) >= 1).all()
