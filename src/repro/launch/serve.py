"""Serving launcher: batched decode with the Twilight engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --requests 8 --prompt-len 96 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.serving import DecodeEngine, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--capacity", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rng = np.random.default_rng(args.seed)
    engine = DecodeEngine(cfg, batch_size=args.batch,
                          cache_capacity=args.capacity, seed=args.seed)

    reqs = []
    for uid in range(args.requests):
        extras = {}
        if cfg.frontend == "audio":
            extras["frames"] = rng.normal(
                size=(args.prompt_len, cfg.d_model)).astype(np.float32)
        elif cfg.frontend == "vision":
            extras["patches"] = rng.normal(
                size=(cfg.n_prefix_tokens, cfg.d_model)).astype(np.float32)
        reqs.append(Request(
            uid=uid,
            prompt=rng.integers(8, cfg.vocab_size, args.prompt_len
                                ).astype(np.int32),
            max_new_tokens=args.max_new,
            extras=extras or None,
        ))

    t0 = time.time()
    results = engine.generate(reqs)
    wall = time.time() - t0
    total_tokens = sum(r.decode_steps for r in results)
    budgets = [r.mean_pruned_budget for r in results]
    print(f"[serve] {cfg.name}: {len(results)} requests, "
          f"{total_tokens} tokens in {wall:.1f}s "
          f"({total_tokens / wall:.1f} tok/s CPU-interpret)")
    print(f"[serve] mean Twilight pruned budget: {np.mean(budgets):.1f} "
          f"tokens (capacity {args.capacity})")


if __name__ == "__main__":
    main()
