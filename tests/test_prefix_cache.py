"""Prefix caching: ref-counted/COW allocator, radix tree, shared decode.

Four layers, mirroring how the feature is built:

* allocator — randomized property tests for the ref-count invariants
  (conservation, reuse only at refcount 0, COW semantics);
* radix tree — insert/match/evict unit tests, including LRU order and the
  refcount-1 eviction gate;
* model — a COW'd page write never mutates the shared source page, and
  chunked prefill fills pool pages identically to the contiguous prefill;
* engine — shared-prefix decode is token-exact against the unshared paged
  oracle for the per-request-state selectors at ragged lengths, with a
  forced COW append and forced pool-pressure eviction, and the
  chunked-prefill jit cache stays within ceil(max_prompt / chunk)
  signatures.

H2O runs paged (per-physical-page accumulated mass in the pool — see
``tests/test_persistent.py`` for the paged-vs-contiguous equivalence), and
runs under prefix sharing too — but *by design* not token-exactly vs the
unshared oracle: a shared prefix page pools every reader's mass, so a
cache-hitting request ranks pages with the fleet's accumulated signal
rather than only its own.  Asserted here as documented behavior.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.serving import DecodeEngine, PrefixCache, Request
from repro.serving.paged_cache import NULL_PAGE, PageAllocator, pages_for

PAGED_SELECTORS = ("full", "quest", "double_sparsity", "streaming")


# ---------------------------------------------------------------------------
# Allocator: ref-count + COW property tests
# ---------------------------------------------------------------------------

def test_refcount_conservation_random_ops():
    """Randomized alloc/share/free against a shadow refcount model: pages
    recycle exactly when their count reaches zero, and
    available + allocated == capacity at every step."""
    rng = np.random.default_rng(1)
    alloc = PageAllocator(17)
    model: dict[int, int] = {}  # page -> refcount
    for _ in range(500):
        op = rng.random()
        if op < 0.4 and alloc.available:
            n = int(rng.integers(1, alloc.available + 1))
            for p in alloc.alloc(n):
                assert p not in model, "page handed out while referenced"
                model[p] = 1
        elif op < 0.65 and model:
            p = int(rng.choice(list(model)))
            alloc.share([p])
            model[p] += 1
        elif model:
            p = int(rng.choice(list(model)))
            alloc.free([p])
            model[p] -= 1
            if model[p] == 0:
                del model[p]
        assert alloc.allocated == frozenset(model)
        for p, c in model.items():
            assert alloc.refcount(p) == c
        assert alloc.available + len(model) == alloc.capacity
    for p in list(model):
        alloc.free([p] * model.pop(p))
    assert alloc.available == alloc.capacity


def test_share_requires_allocated_and_free_guards():
    alloc = PageAllocator(5)
    with pytest.raises(ValueError, match="share unallocated"):
        alloc.share([1])
    a = alloc.alloc(1)
    alloc.share(a)
    alloc.free(a)
    alloc.free(a)  # second reference
    with pytest.raises(ValueError, match="double free"):
        alloc.free(a)
    with pytest.raises(ValueError):
        alloc.free([NULL_PAGE])


def test_cow_semantics():
    alloc = PageAllocator(6)
    (p,) = alloc.alloc(1)
    # Exclusive page: no copy, same page back.
    q, copied = alloc.cow(p)
    assert q == p and not copied and alloc.refcount(p) == 1
    # Shared page: fresh page, our reference moves, the other stays.
    alloc.share([p])
    q, copied = alloc.cow(p)
    assert copied and q != p
    assert alloc.refcount(p) == 1 and alloc.refcount(q) == 1
    with pytest.raises(ValueError):
        alloc.cow(99)


def test_cow_exhaustion_raises():
    alloc = PageAllocator(3)
    pages = alloc.alloc(2)
    alloc.share([pages[0]])
    with pytest.raises(MemoryError):
        alloc.cow(pages[0])


# ---------------------------------------------------------------------------
# Radix tree: insert / match / evict
# ---------------------------------------------------------------------------

def _toks(rng, n):
    return rng.integers(0, 100, n).astype(np.int32)


def test_tree_insert_match_roundtrip():
    rng = np.random.default_rng(0)
    alloc = PageAllocator(17)
    tree = PrefixCache(4, alloc)
    toks = _toks(rng, 11)  # 2 full pages + tail
    pages = alloc.alloc(2)
    assert tree.insert(toks, pages) == 2
    assert all(alloc.refcount(p) == 2 for p in pages)  # owner + tree

    # Exact prefix reuse: longer prompt sharing both pages.
    ext = np.concatenate([toks[:8], _toks(rng, 5)])
    got, n = tree.match(ext)
    assert got == pages and n == 8
    assert all(alloc.refcount(p) == 3 for p in pages)
    alloc.free(got)

    # Divergence after one page matches only the first.
    div = np.concatenate([toks[:4], _toks(rng, 8) + 100])
    got, n = tree.match(div)
    assert got == pages[:1] and n == 4
    alloc.free(got)

    # Sub-page prompts never match (page-granular tree).
    got, n = tree.match(toks[:3])
    assert got == [] and n == 0


def test_tree_first_writer_wins():
    rng = np.random.default_rng(1)
    alloc = PageAllocator(9)
    tree = PrefixCache(4, alloc)
    toks = _toks(rng, 8)
    first = alloc.alloc(2)
    tree.insert(toks, first)
    dup = alloc.alloc(2)
    assert tree.insert(toks, dup) == 0  # nodes exist: duplicate stays private
    assert all(alloc.refcount(p) == 1 for p in dup)
    got, _ = tree.match(toks)
    assert got == first
    alloc.free(got)


def test_tree_evict_lru_and_refcount_gate():
    rng = np.random.default_rng(2)
    alloc = PageAllocator(17)
    tree = PrefixCache(4, alloc)
    cold = _toks(rng, 8)
    hot = _toks(rng, 8) + 100
    cold_pages = alloc.alloc(2)
    tree.insert(cold, cold_pages)
    hot_pages = alloc.alloc(2)
    tree.insert(hot, hot_pages)
    alloc.free(cold_pages)  # only the tree holds these now
    alloc.free(hot_pages)
    got, _ = tree.match(hot)  # touch: hot becomes most-recent AND pinned
    assert tree.reclaimable() == 2  # the cold chain
    avail0 = alloc.available
    # Ask for more than reclaimable: only the cold chain drains (leaf
    # first, then its exposed parent); pinned hot pages survive.
    assert tree.evict(4) == 2
    assert alloc.available == avail0 + 2
    assert tree.match(cold) == ([], 0)
    re_got, n = tree.match(hot)
    assert re_got == got and n == 8
    alloc.free(got)
    alloc.free(re_got)
    # Unpinned now: eviction reclaims hot too.
    assert tree.evict(4) == 2
    assert alloc.available == alloc.capacity


def test_tree_evict_order_is_lru():
    rng = np.random.default_rng(3)
    alloc = PageAllocator(9)
    tree = PrefixCache(4, alloc)
    a, bb = _toks(rng, 4), _toks(rng, 4) + 100
    pa = alloc.alloc(1)
    tree.insert(a, pa)
    pb = alloc.alloc(1)
    tree.insert(bb, pb)
    alloc.free(pa)
    alloc.free(pb)
    got, _ = tree.match(a)  # refresh a: b is now LRU
    alloc.free(got)
    assert tree.evict(1) == 1
    assert tree.match(bb) == ([], 0), "LRU victim is the untouched entry"
    assert tree.match(a)[1] == 4


# ---------------------------------------------------------------------------
# Model: COW never mutates the shared page
# ---------------------------------------------------------------------------

def test_cow_write_leaves_source_page_intact(rng):
    """share → write → COW: the writer lands in its private copy; the
    shared source page's rows and Quest metadata stay bit-identical."""
    from repro.models import (copy_page, init_paged_decode_state, init_params,
                              prefill_chunk)
    cfg = get_smoke_config("qwen2-1.5b")
    ps = cfg.twilight.page_size
    params = init_params(cfg, jax.random.PRNGKey(0))
    alloc = PageAllocator(9)
    state = init_paged_decode_state(cfg, 2, alloc.num_pages)
    pages = alloc.alloc(2)
    max_pages = 4
    pt = np.zeros((max_pages,), np.int32)
    pt[:2] = pages
    prompt = rng.integers(8, cfg.vocab_size, 2 * ps).astype(np.int32)
    _, state, _ = prefill_chunk(params, cfg, state, jnp.asarray(prompt),
                                jnp.asarray(pt), jnp.int32(0), jnp.int32(0),
                                jnp.int32(len(prompt)))

    src = pages[-1]
    snap = {}
    for li, blk in enumerate(state["blocks"]):
        snap[li] = {n: np.asarray(blk[n][:, src * ps:(src + 1) * ps]).copy()
                    for n in ("k", "v", "qk_packed")}
        snap[li]["pmax"] = np.asarray(blk["pmax"][:, src]).copy()

    # COW: copy the shared page, then overwrite its last row in the copy.
    alloc.share([src])  # a second reader appears (prefix-cache role)
    dst, copied = alloc.cow(src)
    assert copied
    state = copy_page(cfg, state, jnp.int32(src), jnp.int32(dst))
    pt2 = pt.copy()
    pt2[1] = dst
    other = (prompt[-1] + 1) % cfg.vocab_size
    _, state, _ = prefill_chunk(params, cfg, state,
                                jnp.asarray(np.full((ps,), other, np.int32)),
                                jnp.asarray(pt2), jnp.int32(1),
                                jnp.int32(len(prompt) - 1), jnp.int32(1))

    for li, blk in enumerate(state["blocks"]):
        for n in ("k", "v", "qk_packed"):
            np.testing.assert_array_equal(
                np.asarray(blk[n][:, src * ps:(src + 1) * ps]), snap[li][n],
                err_msg=f"layer {li} {n}: shared page mutated")
        np.testing.assert_array_equal(np.asarray(blk["pmax"][:, src]),
                                      snap[li]["pmax"])
        # ... and the write really happened, in the private copy.
        assert not np.array_equal(
            np.asarray(blk["k"][:, dst * ps:(dst + 1) * ps]), snap[li]["k"])


# ---------------------------------------------------------------------------
# Engine: shared-prefix decode == unshared paged decode
# ---------------------------------------------------------------------------

def _shared_requests(rng, cfg, prefix_len=24):
    """Ragged workload: four prefix-sharers (one fully cached duplicate,
    page-aligned, forcing a COW append), one unrelated prompt.  The first
    two admit concurrently into an empty tree; the later arrivals hit."""
    prefix = rng.integers(8, cfg.vocab_size, prefix_len).astype(np.int32)

    def ext(uid, tail, mn):
        t = rng.integers(8, cfg.vocab_size, tail).astype(np.int32)
        return Request(uid=uid, prompt=np.concatenate([prefix, t]),
                       max_new_tokens=mn)

    return [
        ext(0, 9, 4),
        ext(1, 4, 3),
        Request(uid=2, prompt=prefix.copy(), max_new_tokens=3),  # COW
        Request(uid=3,
                prompt=rng.integers(8, cfg.vocab_size, 13).astype(np.int32),
                max_new_tokens=3),
        ext(4, 6, 3),  # late sharer: matches the resident prefix pages
    ]


@pytest.mark.parametrize("selector", PAGED_SELECTORS)
def test_shared_prefix_matches_unshared(rng, selector):
    cfg = get_smoke_config("qwen2-1.5b")
    cfg = cfg.replace(twilight=dataclasses.replace(
        cfg.twilight, selector=selector))
    reqs = _shared_requests(rng, cfg)
    base = DecodeEngine(cfg, batch_size=2, cache_capacity=64, seed=7,
                        paged=True)
    shared = DecodeEngine(cfg, params=base.params, batch_size=2,
                          cache_capacity=64, seed=7, paged=True,
                          prefix_share=True)
    want = {r.uid: r.tokens for r in base.generate(reqs)}
    got = {r.uid: r.tokens for r in shared.generate(reqs)}
    assert got == want
    assert shared.last_prefix_hits >= 2, "prefix reuse must actually happen"
    assert shared.last_prefix_tokens > 0
    assert shared.last_cow_copies >= 1, \
        "the fully-cached duplicate must trigger a COW append"


def test_shared_prefix_forced_eviction_matches(rng):
    """A pool too small to retain every retired prompt forces LRU eviction
    of cold prefix pages; tokens must still match the unshared oracle."""
    cfg = get_smoke_config("qwen2-1.5b")
    ps = cfg.twilight.page_size
    reqs = [Request(uid=i,
                    prompt=rng.integers(8, cfg.vocab_size, 24
                                        ).astype(np.int32),
                    max_new_tokens=4)
            for i in range(3)]
    # 7 allocatable pages; each request needs 4 (3 prompt + 1 boundary) and
    # leaves 3 cached — the third admission must evict.
    base = DecodeEngine(cfg, batch_size=1, cache_capacity=64, seed=7,
                        paged=True, num_pages=8)
    shared = DecodeEngine(cfg, params=base.params, batch_size=1,
                          cache_capacity=64, seed=7, paged=True, num_pages=8,
                          prefix_share=True)
    want = {r.uid: r.tokens for r in base.generate(reqs)}
    got = {r.uid: r.tokens for r in shared.generate(reqs)}
    assert got == want
    assert shared.last_evictions >= 1, "pool sizing must force eviction"
    assert pages_for(24, ps) == 3


def test_shared_prefix_preemption_matches(rng):
    """Prefix sharing + a tight pool that forces recompute preemption:
    greedy tokens still match, and restarted requests re-match their own
    cached prefix instead of re-prefilling from scratch."""
    cfg = get_smoke_config("qwen2-1.5b")
    reqs = [Request(uid=i,
                    prompt=rng.integers(8, cfg.vocab_size, 17
                                        ).astype(np.int32),
                    max_new_tokens=20)
            for i in range(2)]
    base = DecodeEngine(cfg, batch_size=1, cache_capacity=40, seed=7,
                        paged=True)
    tight = DecodeEngine(cfg, params=base.params, batch_size=2,
                         cache_capacity=40, seed=7, paged=True, num_pages=9,
                         prefix_share=True)
    want = {r.uid: r.tokens for r in base.generate(reqs)}
    got = {r.uid: r.tokens for r in tight.generate(reqs)}
    assert tight.last_preemptions > 0, "pool sizing must force preemption"
    assert got == want


def test_h2o_prefix_share_serves_with_pooled_mass(rng):
    """H2O now runs under prefix sharing: shared pages carry pooled
    physical-page mass, so cache-hitting requests serve fine (hits + COW
    still fire) — their page ranking just blends every reader's signal
    instead of being per-request (the documented deviation from the
    unshared oracle; exactness without sharing is covered in
    tests/test_persistent.py)."""
    cfg = get_smoke_config("qwen2-1.5b")
    cfg = cfg.replace(twilight=dataclasses.replace(cfg.twilight,
                                                   selector="h2o"))
    reqs = _shared_requests(rng, cfg)
    shared = DecodeEngine(cfg, batch_size=2, cache_capacity=64, seed=7,
                          paged=True, prefix_share=True)
    results = {r.uid: r for r in shared.generate(reqs)}
    assert set(results) == {r.uid for r in reqs}
    for r in reqs:
        got = results[r.uid]
        assert len(got.tokens) == r.max_new_tokens
        assert all(0 <= t < cfg.vocab_size for t in got.tokens)
    assert shared.last_prefix_hits >= 2, "prefix reuse must actually happen"
    assert shared.last_cow_copies >= 1


def test_chunked_prefill_jit_signatures(rng):
    """Many distinct prompt lengths compile at most ceil(max_prompt/chunk)
    chunk signatures (bucketed chunks) — not one per exact length, which is
    what the unshared paged path pays."""
    cfg = get_smoke_config("qwen2-1.5b")
    ps = cfg.twilight.page_size
    engine = DecodeEngine(cfg, batch_size=2, cache_capacity=64, seed=0,
                          paged=True, prefix_share=True,
                          prefill_chunk_pages=2)
    chunk = engine.chunk_tokens
    assert chunk == 2 * ps
    lengths = [5, 9, 14, 17, 23, 26, 31, 38, 45, 53]
    reqs = [Request(uid=i,
                    prompt=rng.integers(8, cfg.vocab_size, L
                                        ).astype(np.int32),
                    max_new_tokens=2)
            for i, L in enumerate(lengths)]
    engine.generate(reqs)
    n_sig = engine._chunk._cache_size()
    assert n_sig <= -(-max(lengths) // chunk), n_sig


def test_prefix_share_requires_attention_only():
    cfg = get_smoke_config("jamba-1.5-large-398b")
    with pytest.raises(ValueError, match="attention-only"):
        DecodeEngine(cfg, batch_size=1, cache_capacity=64, paged=True,
                     prefix_share=True)
