"""Assigned-architecture registry.

``get_config(arch_id)`` returns the exact full config from the public pool;
``get_smoke_config(arch_id)`` returns the reduced same-family variant used by
the CPU smoke tests (≤2 layers, d_model ≤ 512, ≤4 experts).
"""

from __future__ import annotations

import importlib

from repro.models.common import ModelConfig

ARCH_IDS = [
    "deepseek-moe-16b",
    "qwen2-1.5b",
    "llama4-scout-17b-a16e",
    "starcoder2-15b",
    "moonshot-v1-16b-a3b",
    "jamba-1.5-large-398b",
    "qwen3-32b",
    "seamless-m4t-medium",
    "xlstm-350m",
    "internvl2-1b",
]


def _module(arch_id: str):
    return importlib.import_module(
        "repro.configs." + arch_id.replace("-", "_").replace(".", "_"))


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCH_IDS:
        raise ValueError(f"unknown arch {arch_id!r}; have {ARCH_IDS}")
    return _module(arch_id).config()


def get_smoke_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCH_IDS:
        raise ValueError(f"unknown arch {arch_id!r}; have {ARCH_IDS}")
    return _module(arch_id).smoke_config()


def list_archs() -> list[str]:
    return list(ARCH_IDS)
