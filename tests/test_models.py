"""Per-architecture smoke tests + cross-path consistency.

Every assigned arch: reduced config, one forward + one train-grad + prefill
+ decode on CPU; shapes and finiteness asserted.  Consistency: prefill
logits == forward logits; decode continuation == teacher-forced forward.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import (
    count_params,
    decode_step,
    forward,
    init_params,
    prefill,
)
from repro.models.model import layer_schedule


def _batch(cfg, rng, b=2, s=32):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)))}
    if cfg.frontend == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, 16, cfg.d_model)), jnp.float32)
    if cfg.frontend == "vision":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_prefix_tokens, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_decode(arch, rng):
    cfg = get_smoke_config(arch)
    assert cfg.n_layers <= 4 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    logits, aux = forward(params, cfg, batch)
    s_total = 32 + (cfg.n_prefix_tokens if cfg.frontend == "vision" else 0)
    assert logits.shape == (2, s_total, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    lg, state = prefill(params, cfg, batch, n_max=64)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (2,)))
    lg2, state2, stats = decode_step(params, cfg, state, tok)
    assert lg2.shape == (2, cfg.padded_vocab)
    assert np.isfinite(np.asarray(lg2, np.float32)).all()
    assert int(state2["pos"]) == int(state["pos"]) + 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_grad(arch, rng):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    batch["labels"] = batch["tokens"]

    from repro.training.loop import loss_fn
    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, cfg, batch, remat=False, z_loss=1e-4)
    assert np.isfinite(float(loss))
    assert all(np.isfinite(np.asarray(g, np.float32)).all()
               for g in jax.tree_util.tree_leaves(grads))


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "jamba-1.5-large-398b",
                                  "xlstm-350m", "seamless-m4t-medium",
                                  "internvl2-1b"])
def test_prefill_matches_forward(arch, rng):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(1))
    batch = _batch(cfg, rng)
    lg_fwd, _ = forward(params, cfg, batch)
    lg_pre, _ = prefill(params, cfg, batch, n_max=64)
    np.testing.assert_allclose(
        np.asarray(lg_pre, np.float32), np.asarray(lg_fwd, np.float32),
        rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "deepseek-moe-16b",
                                  "jamba-1.5-large-398b", "xlstm-350m"])
def test_decode_matches_teacher_forcing(arch, rng):
    """decode_step must reproduce the teacher-forced forward logits.

    Twilight is configured with p=0.999 + full selector here so the sparse
    path is (numerically) the full computation.
    """
    import dataclasses
    cfg = get_smoke_config(arch)
    cfg = cfg.replace(twilight=dataclasses.replace(
        cfg.twilight, selector="full", p=0.9999, candidate_frac=1.0,
        min_candidate=64))
    if cfg.moe is not None:
        # Capacity-based dropping differs between the full-sequence forward
        # (capacity over the whole batch) and single-token decode; raise
        # the capacity so no token drops in either path and the two are
        # numerically comparable.
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    params = init_params(cfg, jax.random.PRNGKey(2))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 24)))
    full_logits, _ = forward(params, cfg, {"tokens": toks})

    _, state = prefill(params, cfg, {"tokens": toks[:, :16]}, n_max=32)
    logits_seq = []
    for t in range(16, 24):
        lg, state, _ = decode_step(params, cfg, state, toks[:, t])
        logits_seq.append(lg)
    dec = np.stack([np.asarray(l, np.float32) for l in logits_seq], axis=1)
    ref = np.asarray(full_logits[:, 16:24], np.float32)
    # bf16 params + different reduction orders between the fused full-seq
    # path and the stepwise path: allow 1e-1 on raw logits.
    from repro.models import block_pattern
    hybrid_moe = cfg.moe is not None and "mamba" in block_pattern(cfg)
    if not hybrid_moe:
        np.testing.assert_allclose(dec, ref, rtol=5e-2, atol=1e-1)
    else:
        # Hybrid + MoE (Jamba): the decode path's benign bf16 divergence
        # (<0.1 logits with MoE removed) lands on the f32 router's top-k
        # boundary for a few near-tied tokens, and a flipped expert pair
        # moves those tokens' logits by O(1).  That is fp-order chaos, not
        # a decode bug, so assert the bulk matches and the flip-affected
        # tail is small and bounded (measured across seeds: <=6.8% of
        # elements beyond tolerance, max deviation 1.9).
        err = np.abs(dec - ref)
        beyond = err > (1e-1 + 5e-2 * np.abs(ref))
        assert beyond.mean() < 0.15, f"{beyond.mean():.3f} of logits diverge"
        assert err.max() < 4.0, f"max logit deviation {err.max():.2f}"


def test_layer_schedules():
    cfg = get_config("jamba-1.5-large-398b")
    specs, repeats = layer_schedule(cfg)
    assert len(specs) == 8 and repeats == 9
    kinds = [s.kind for s in specs]
    assert kinds.count("attn") == 1 and kinds.count("mamba") == 7
    assert sum(s.is_moe for s in specs) == 4

    cfg = get_config("xlstm-350m")
    specs, repeats = layer_schedule(cfg)
    assert [s.kind for s in specs].count("slstm") == 1
    assert len(specs) * repeats == 24


def test_full_config_param_counts():
    """Full configs approximate their nameplate sizes (no init, eval_shape)."""
    import functools
    expected = {
        "deepseek-moe-16b": (14e9, 21e9),
        "qwen2-1.5b": (1.2e9, 2.2e9),
        # Our block calculus uses SwiGLU (3 FFN matrices) uniformly; the
        # original StarCoder2 uses a 2-matrix GELU MLP, so the same pool
        # dims give ~22B here vs the 15B nameplate.
        "starcoder2-15b": (14e9, 23e9),
        "qwen3-32b": (28e9, 36e9),
        "jamba-1.5-large-398b": (350e9, 440e9),
        # Pool dims with proj_factor=2 mLSTM internals give ~0.6B; the
        # released 350M recipe uses leaner inner projections.
        "xlstm-350m": (0.25e9, 0.7e9),
        "internvl2-1b": (0.4e9, 1.1e9),
        # Pool spec says 48L (vs Moonlight's released 27L), so the same
        # fine-grained-MoE dims land at ~29B total here.
        "moonshot-v1-16b-a3b": (14e9, 30e9),
        "llama4-scout-17b-a16e": (90e9, 120e9),  # total (active 17B)
        "seamless-m4t-medium": (0.8e9, 1.7e9),
    }
    for arch, (lo, hi) in expected.items():
        cfg = get_config(arch)
        struct = jax.eval_shape(functools.partial(init_params, cfg),
                                jax.random.PRNGKey(0))
        n = sum(int(np.prod(x.shape))
                for x in jax.tree_util.tree_leaves(struct))
        assert lo <= n <= hi, f"{arch}: {n / 1e9:.2f}B params not in [{lo / 1e9}, {hi / 1e9}]"
