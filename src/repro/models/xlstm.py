"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory) [2405.04517].

Both use exponential gating with the max-state stabilizer.  Train path is a
time scan (O(1) memory); decode is the single-step recurrence — xLSTM has no
KV cache, so the Twilight technique is inapplicable here (DESIGN
§Arch-applicability) and `long_500k` decodes natively in O(1).

State shapes per layer (batch b, heads nh, head dim dh):
  mLSTM: C (b, nh, dh, dh), n (b, nh, dh), m (b, nh), conv tail
  sLSTM: c, n, h (b, nh, dh), m (b, nh)
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig

Params = dict[str, Any]


def _mlstm_dims(cfg: ModelConfig) -> tuple[int, int, int]:
    d_inner = int(cfg.xlstm.proj_factor * cfg.d_model)
    nh = cfg.n_heads
    # Round the inner dim to a multiple of heads.
    d_inner -= d_inner % nh
    return d_inner, nh, d_inner // nh


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(cfg: ModelConfig, key) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    d_inner, nh, dh = _mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    s_in = cfg.d_model ** -0.5
    s_inner = d_inner ** -0.5
    conv_k = cfg.xlstm.conv_kernel
    return {
        "up": (jax.random.normal(ks[0], (cfg.d_model, 2 * d_inner), jnp.float32)
               * s_in).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (conv_k, d_inner), jnp.float32)
                   * (conv_k ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "wq": (jax.random.normal(ks[2], (d_inner, d_inner), jnp.float32)
               * s_inner).astype(dtype),
        "wk": (jax.random.normal(ks[3], (d_inner, d_inner), jnp.float32)
               * s_inner).astype(dtype),
        "wv": (jax.random.normal(ks[4], (d_inner, d_inner), jnp.float32)
               * s_inner).astype(dtype),
        "w_if": (jax.random.normal(ks[5], (d_inner, 2 * nh), jnp.float32)
                 * s_inner).astype(dtype),
        "b_if": jnp.concatenate([jnp.full((nh,), -2.0), jnp.full((nh,), 2.0)]
                                ).astype(dtype),
        "skip_gate": (jax.random.normal(ks[6], (d_inner, d_inner), jnp.float32)
                      * s_inner).astype(dtype),
        "down": (jax.random.normal(ks[7], (d_inner, cfg.d_model), jnp.float32)
                 * s_inner).astype(dtype),
    }


def _mlstm_gates_qkv(params: Params, cfg: ModelConfig, x: jax.Array,
                     conv_tail: jax.Array | None):
    """x: (b, s, d_model) -> q,k,v (b,s,nh,dh), i,f (b,s,nh), z, new tail."""
    d_inner, nh, dh = _mlstm_dims(cfg)
    up = x @ params["up"]
    u, z = jnp.split(up, 2, axis=-1)
    conv_k = params["conv_w"].shape[0]
    if conv_tail is None:
        conv_tail = jnp.zeros((x.shape[0], conv_k - 1, d_inner), u.dtype)
    xp = jnp.concatenate([conv_tail, u], axis=1)
    new_tail = xp[:, -(conv_k - 1):]
    uc = sum(xp[:, i:i + u.shape[1]] * params["conv_w"][i] for i in range(conv_k))
    uc = jax.nn.silu(uc + params["conv_b"])
    b, s, _ = u.shape
    q = (uc @ params["wq"]).reshape(b, s, nh, dh)
    k = (uc @ params["wk"]).reshape(b, s, nh, dh) * (dh ** -0.5)
    v = (u @ params["wv"]).reshape(b, s, nh, dh)
    gates = (uc @ params["w_if"]) + params["b_if"]
    i_pre, f_pre = jnp.split(gates.astype(jnp.float32), 2, axis=-1)  # (b,s,nh)
    return q, k, v, i_pre, f_pre, z, new_tail


def _mlstm_step(carry, inp):
    C, n, m = carry  # (b,nh,dh,dh), (b,nh,dh), (b,nh)
    q, k, v, i_pre, f_pre = inp
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    log_f = -jax.nn.softplus(-f_pre)  # log sigmoid(f)
    m_new = jnp.maximum(log_f + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)  # (b, nh)
    f_g = jnp.exp(log_f + m - m_new)
    C = f_g[..., None, None] * C + i_g[..., None, None] * (
        vf[..., :, None] * kf[..., None, :])  # v k^T
    n = f_g[..., None] * n + i_g[..., None] * kf
    num = jnp.einsum("bhij,bhj->bhi", C, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, qf)),
                      jnp.exp(-m_new))[..., None]
    h = num / den
    return (C, n, m_new), h


def mlstm_apply(params: Params, cfg: ModelConfig, x: jax.Array,
                *, return_state: bool = False, chunk: int = 512):
    """Full-sequence mLSTM.

    Uses the **chunkwise-parallel** form (intra-chunk quadratic with decay
    matrix, inter-chunk recurrence on the matrix memory) whenever the
    sequence divides the chunk size — the per-timestep recurrent scan would
    otherwise stash a (b, nh, dh, dh) matrix state per step for the
    backward pass (terabytes at 4k x 398 layers-equivalents); chunkwise
    stores one carry per chunk instead.  Falls back to the step scan for
    short/odd lengths, and the step scan remains the correctness oracle.
    """
    b, s, _ = x.shape
    d_inner, nh, dh = _mlstm_dims(cfg)
    q, k, v, i_pre, f_pre, z, conv_tail = _mlstm_gates_qkv(params, cfg, x, None)
    if s % chunk == 0 and s > chunk:
        (C, n, m), h = _mlstm_chunkwise(q, k, v, i_pre, f_pre, chunk)
    else:
        carry = (jnp.zeros((b, nh, dh, dh), jnp.float32),
                 jnp.zeros((b, nh, dh), jnp.float32),
                 jnp.zeros((b, nh), jnp.float32))
        xs = tuple(jnp.moveaxis(t, 1, 0) for t in (q, k, v, i_pre, f_pre))
        (C, n, m), hs = jax.lax.scan(_mlstm_step, carry, xs)
        h = jnp.moveaxis(hs, 0, 1)  # (b, s, nh, dh)
    h = h.reshape(b, s, d_inner).astype(x.dtype)
    h = h * jax.nn.silu(z)
    out = h @ params["down"]
    if return_state:
        return out, {"C": C, "n": n, "m": m, "conv": conv_tail}
    return out


def _mlstm_chunkwise(q, k, v, i_pre, f_pre, chunk: int):
    """Chunkwise-parallel stabilized mLSTM.

    q,k,v: (b, s, nh, dh); i_pre, f_pre: (b, s, nh).
    Returns final (C, n, m) state and h (b, s, nh, dh).
    """
    b, s, nh, dh = q.shape
    nc = s // chunk

    def to_chunks(t, trailing):
        return jnp.moveaxis(
            t.reshape((b, nc, chunk) + trailing), 1, 0)  # (nc, b, chunk, ...)

    qc = to_chunks(q.astype(jnp.float32), (nh, dh))
    kc = to_chunks(k.astype(jnp.float32), (nh, dh))
    vc = to_chunks(v.astype(jnp.float32), (nh, dh))
    ic = to_chunks(i_pre, (nh,))
    fc = to_chunks(f_pre, (nh,))

    def chunk_body(carry, inp):
        Ct, nt, m_prev = carry  # (b,nh,dh,dh), (b,nh,dh), (b,nh)
        qb, kb, vb, ib, fb = inp  # (b, c, nh, ...)
        log_f = -jax.nn.softplus(-fb)  # (b, c, nh)
        blc = jnp.cumsum(log_f, axis=1)  # inclusive within-chunk cumsum
        B = blc[:, -1]  # (b, nh)

        # Intra-chunk decay matrix D[t, s] = blc_t - blc_s + i_s (s <= t).
        D = (blc[:, :, None, :] - blc[:, None, :, :]
             + ib[:, None, :, :])  # (b, t, s, nh)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        D = jnp.where(tri[None, :, :, None], D, -jnp.inf)
        m_intra = jnp.max(D, axis=2)  # (b, t, nh)
        m_inter = m_prev[:, None, :] + blc  # (b, t, nh)
        m_t = jnp.maximum(m_inter, m_intra)

        W = jnp.exp(D - m_t[:, :, None, :])  # (b, t, s, nh)
        S = jnp.einsum("bthd,bshd->btsh", qb, kb)  # q_t . k_s
        num_intra = jnp.einsum("btsh,btsh,bshd->bthd", W, S, vb)
        den_intra = jnp.einsum("btsh,btsh->bth", W, S)

        scale_inter = jnp.exp(m_inter - m_t)  # (b, t, nh)
        Cq = jnp.einsum("bhij,bthj->bthi", Ct, qb)  # (b, t, nh, dh)
        num = num_intra + scale_inter[..., None] * Cq
        den_inter = jnp.einsum("bhj,bthj->bth", nt, qb)
        den = den_intra + scale_inter * den_inter
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        h = num / den  # (b, t, nh, dh)

        # Carry update to the chunk boundary.
        src = B[:, None, :] - blc + ib  # (b, s, nh): decay of source s to end
        m_state = jnp.maximum(m_prev + B, jnp.max(src, axis=1))  # (b, nh)
        w_src = jnp.exp(src - m_state[:, None, :])  # (b, s, nh)
        C_new = (jnp.exp(m_prev + B - m_state)[..., None, None] * Ct
                 + jnp.einsum("bsh,bshi,bshj->bhij", w_src, vb, kb))
        n_new = (jnp.exp(m_prev + B - m_state)[..., None] * nt
                 + jnp.einsum("bsh,bshj->bhj", w_src, kb))
        return (C_new, n_new, m_state), h

    carry = (jnp.zeros((b, nh, dh, dh), jnp.float32),
             jnp.zeros((b, nh, dh), jnp.float32),
             jnp.zeros((b, nh), jnp.float32))
    carry, hs = jax.lax.scan(jax.checkpoint(chunk_body), carry,
                             (qc, kc, vc, ic, fc))
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, nh, dh)
    return carry, h


def mlstm_init_state(cfg: ModelConfig, batch: int) -> dict[str, jax.Array]:
    d_inner, nh, dh = _mlstm_dims(cfg)
    conv_k = cfg.xlstm.conv_kernel
    return {
        "C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, nh, dh), jnp.float32),
        "m": jnp.zeros((batch, nh), jnp.float32),
        "conv": jnp.zeros((batch, conv_k - 1, d_inner), jnp.dtype(cfg.dtype)),
    }


def mlstm_decode_step(params: Params, cfg: ModelConfig, x: jax.Array,
                      state: dict[str, jax.Array]):
    d_inner, nh, dh = _mlstm_dims(cfg)
    q, k, v, i_pre, f_pre, z, new_tail = _mlstm_gates_qkv(
        params, cfg, x[:, None, :], state["conv"])
    carry = (state["C"], state["n"], state["m"])
    (C, n, m), h = _mlstm_step(carry, (q[:, 0], k[:, 0], v[:, 0],
                                       i_pre[:, 0], f_pre[:, 0]))
    h = h.reshape(x.shape[0], d_inner).astype(x.dtype) * jax.nn.silu(z[:, 0])
    out = h @ params["down"]
    return out, {"C": C, "n": n, "m": m, "conv": new_tail}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(cfg: ModelConfig, key) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    nh, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    ks = jax.random.split(key, 3)
    s_in = cfg.d_model ** -0.5
    return {
        # Input projections for i, f, z, o gates.
        "w_gates": (jax.random.normal(ks[0], (cfg.d_model, 4 * cfg.d_model),
                                      jnp.float32) * s_in).astype(dtype),
        # Block-diagonal (per-head) recurrent weights.
        "r_gates": (jax.random.normal(ks[1], (4, nh, dh, dh), jnp.float32)
                    * (dh ** -0.5)).astype(dtype),
        "b_gates": jnp.zeros((4 * cfg.d_model,), dtype),
        "down": (jax.random.normal(ks[2], (cfg.d_model, cfg.d_model), jnp.float32)
                 * s_in).astype(dtype),
    }


def _slstm_step(params_f32, cfg: ModelConfig, carry, wx_t):
    """wx_t: (b, 4*d_model) precomputed input contribution at time t."""
    nh, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    c, n, h, m = carry  # (b, nh, dh) x3, (b, nh)
    r = params_f32  # (4, nh, dh, dh)
    rh = jnp.einsum("ghij,bhj->bghi", r, h)  # (b, 4, nh, dh)
    pre = wx_t.reshape(wx_t.shape[0], 4, nh, dh).astype(jnp.float32) + rh
    i_pre, f_pre, z_pre, o_pre = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    # Stabilized exponential gating (per head: use max over the head dim of
    # the raw gate pre-activations as in the xLSTM reference).
    log_f = -jax.nn.softplus(-f_pre)  # (b, nh, dh)
    m_new = jnp.maximum(jnp.max(log_f, -1) + m, jnp.max(i_pre, -1))  # (b, nh)
    i_g = jnp.exp(i_pre - m_new[..., None])
    f_g = jnp.exp(log_f + m[..., None] - m_new[..., None])
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    c = f_g * c + i_g * z
    n = f_g * n + i_g
    h_new = o * c / jnp.maximum(n, 1.0)
    return (c, n, h_new, m_new), h_new


def slstm_apply(params: Params, cfg: ModelConfig, x: jax.Array,
                *, return_state: bool = False):
    b, s, d = x.shape
    nh, dh = cfg.n_heads, d // cfg.n_heads
    wx = x @ params["w_gates"] + params["b_gates"]  # (b, s, 4d) — bf16 xs;
    # the step computes in f32 (saved scan inputs stay half-size).
    carry = (jnp.zeros((b, nh, dh), jnp.float32),
             jnp.zeros((b, nh, dh), jnp.float32),
             jnp.zeros((b, nh, dh), jnp.float32),
             jnp.zeros((b, nh), jnp.float32))
    r = params["r_gates"].astype(jnp.float32)

    def step(carry, wx_t):
        return _slstm_step(r, cfg, carry, wx_t)

    (c, n, h_st, m), hs = jax.lax.scan(step, carry,
                                       jnp.moveaxis(wx, 1, 0).astype(x.dtype))
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    out = h @ params["down"]
    if return_state:
        return out, {"c": c, "n": n, "h": h_st, "m": m}
    return out


def slstm_init_state(cfg: ModelConfig, batch: int) -> dict[str, jax.Array]:
    nh, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    z = lambda *shape: jnp.zeros(shape, jnp.float32)  # noqa: E731
    return {"c": z(batch, nh, dh), "n": z(batch, nh, dh),
            "h": z(batch, nh, dh), "m": z(batch, nh)}


def slstm_decode_step(params: Params, cfg: ModelConfig, x: jax.Array,
                      state: dict[str, jax.Array]):
    wx = x @ params["w_gates"] + params["b_gates"]  # (b, 4d)
    carry = (state["c"], state["n"], state["h"], state["m"])
    r = params["r_gates"].astype(jnp.float32)
    (c, n, h, m), h_out = _slstm_step(r, cfg, carry, wx)
    out = h_out.reshape(x.shape[0], -1).astype(x.dtype) @ params["down"]
    return out, {"c": c, "n": n, "h": h, "m": m}
