"""Batched decode engine with Twilight sparse attention.

A deliberately real serving loop: fixed batch slots, request queue,
continuous batching (a finished slot is refilled at the next prefill
boundary), greedy/nucleus sampling, per-step Twilight budget telemetry.

The decode step is jitted once per (batch, cache_capacity) and reused; all
request dynamism is data (positions, live masks), never shapes — the same
static-shape discipline the TPU adaptation imposes on the kernels.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_params, prefill
from repro.models.common import ModelConfig
from repro.serving.sampler import sample_token

Tree = Any


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (s,) int32
    max_new_tokens: int = 32
    greedy: bool = True
    extras: dict | None = None  # modality-frontend embeddings


@dataclasses.dataclass
class GenerationResult:
    uid: int
    tokens: list[int]
    prompt_len: int
    decode_steps: int
    mean_pruned_budget: float
    wall_s: float


class DecodeEngine:
    """Continuous-batching engine around (prefill, decode_step)."""

    def __init__(self, cfg: ModelConfig, params: Tree | None = None, *,
                 batch_size: int = 8, cache_capacity: int = 512, seed: int = 0):
        self.cfg = cfg
        self.batch_size = batch_size
        self.cache_capacity = cache_capacity
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else init_params(cfg, key)
        self._sample_key = jax.random.PRNGKey(seed + 1)

        self._prefill = jax.jit(
            lambda p, batch: prefill(p, cfg, batch, cache_capacity))
        self._decode = jax.jit(lambda p, st, tok: decode_step(p, cfg, st, tok))

    # -- single-batch generation (prompts padded to a common length) --------

    def generate(self, requests: list[Request]) -> list[GenerationResult]:
        """Serve a wave of requests (continuous batching across waves)."""
        results: list[GenerationResult] = []
        queue = list(requests)
        while queue:
            wave = queue[:self.batch_size]
            queue = queue[self.batch_size:]
            results.extend(self._serve_wave(wave))
        return results

    def _serve_wave(self, wave: list[Request]) -> list[GenerationResult]:
        t0 = time.time()
        b = len(wave)
        s = max(len(r.prompt) for r in wave)
        s = min(s, self.cache_capacity - max(r.max_new_tokens for r in wave))
        toks = np.zeros((b, s), np.int32)
        for i, r in enumerate(wave):
            pr = r.prompt[-s:]
            toks[i, -len(pr):] = pr  # left-pad with token 0
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.frontend == "audio":
            frames = np.stack([r.extras["frames"] for r in wave])
            batch["frames"] = jnp.asarray(frames)
        elif self.cfg.frontend == "vision":
            patches = np.stack([r.extras["patches"] for r in wave])
            batch["patches"] = jnp.asarray(patches)

        logits, state = self._prefill(self.params, batch)
        last = logits[:, -1, :self.cfg.vocab_size]  # drop padded vocab rows
        max_new = max(r.max_new_tokens for r in wave)
        greedy = all(r.greedy for r in wave)
        # The decode loop stays async: tokens and the budget accumulator
        # live on device and are fetched ONCE per wave.  A float()/asarray()
        # inside the loop would block on the device every token and
        # serialize dispatch against compute.
        out_toks_dev = []
        budget_sum = jnp.zeros((), jnp.float32)
        for step in range(max_new):
            self._sample_key, k = jax.random.split(self._sample_key)
            tok = sample_token(k, last, greedy=greedy)
            out_toks_dev.append(tok)
            last, state, stats = self._decode(self.params, state, tok)
            last = last[:, :self.cfg.vocab_size]
            budget_sum = budget_sum + stats["mean_pruned_budget"]

        out_tokens = (np.stack([np.asarray(t) for t in out_toks_dev], axis=1)
                      if out_toks_dev else np.zeros((b, 0), np.int32))
        mean_budget = float(budget_sum) / max_new if max_new else 0.0
        wall = time.time() - t0
        results = []
        for i, r in enumerate(wave):
            results.append(GenerationResult(
                uid=r.uid,
                tokens=out_tokens[i, :r.max_new_tokens].tolist(),
                prompt_len=len(r.prompt),
                decode_steps=r.max_new_tokens,
                mean_pruned_budget=mean_budget,
                wall_s=wall,
            ))
        return results
