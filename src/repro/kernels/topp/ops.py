"""Public wrapper: top-p mask over (b, heads, n) normalized weights."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.topp import ToppResult
from repro.kernels.topp.kernel import topp_threshold_rows


def topp_mask(
    weights: jax.Array,  # (b, h, n) normalized attention weights
    p: jax.Array | float,
    *,
    iters: int = 24,
    interpret: bool | None = None,
) -> ToppResult:
    b, h, n = weights.shape
    rows = weights.reshape(b * h, n).astype(jnp.float32)
    thresh, budget = topp_threshold_rows(
        rows, jnp.asarray(p, jnp.float32), iters=iters, interpret=interpret
    )
    thresh = thresh.reshape(b, h)
    mask = weights >= thresh[..., None]
    return ToppResult(mask=mask, threshold=thresh,
                      budget=budget.reshape(b, h))
