from repro.serving.engine import DecodeEngine, GenerationResult, Request
from repro.serving.paged_cache import NULL_PAGE, PageAllocator
from repro.serving.prefix_cache import PrefixCache
from repro.serving.sampler import sample_token, top_p_sample

__all__ = ["DecodeEngine", "GenerationResult", "NULL_PAGE", "PageAllocator",
           "PrefixCache", "Request", "sample_token", "top_p_sample"]
