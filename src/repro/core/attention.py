"""Attention math used across the framework.

Pure-jnp implementations; the Pallas kernels in ``repro.kernels`` implement
the hot decode path and are validated against these.

Shapes follow the cache layout (b, n, hkv, d); queries are (b, hq, d) for
single-token decode and (b, s, hq, d) for prefill/training.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant as quant_lib
from repro.core.topp import masked_softmax

__all__ = [
    "full_decode_attention",
    "masked_sparse_decode_attention",
    "compact_decode_attention",
    "gather_kv_heads",
    "gather_quantized_kv_heads",
    "gathered_sparse_decode_attention",
    "mha_attention",
    "attention_error",
]


def _expand_gqa(x: jax.Array, hq: int) -> jax.Array:
    """(b, n, hkv, d) -> (b, n, hq, d) by repeating each KV head over its group."""
    b, n, hkv, d = x.shape
    if hq == hkv:
        return x
    return jnp.repeat(x, hq // hkv, axis=2)


def full_decode_attention(
    q: jax.Array,  # (b, hq, d)
    keys: jax.Array,  # (b, n, hkv, d)
    values: jax.Array,  # (b, n, hkv, d)
    *,
    length: jax.Array | None = None,  # (b,) valid prefix lengths
) -> jax.Array:
    """Exact single-token decode attention (the paper's "Full" baseline)."""
    b, n, hkv, d = keys.shape
    hq = q.shape[1]
    mask = None
    if length is not None:
        mask = (jnp.arange(n)[None, :] < length[:, None])[:, None, :]  # (b,1,n)
    k = _expand_gqa(keys, hq)
    v = _expand_gqa(values, hq)
    # Keep K/V in cache dtype; accumulate in f32 on the MXU.  Casting the
    # cache to f32 here gets hoisted across the whole layer stack by XLA
    # (a 2x cache-sized f32 buffer) — measured on qwen3 decode_32k.
    scores = jnp.einsum("bhd,bnhd->bhn", q.astype(k.dtype), k,
                        preferred_element_type=jnp.float32)
    scores /= jnp.sqrt(jnp.asarray(d, jnp.float32))
    w = masked_softmax(scores, mask)
    out = jnp.einsum("bhn,bnhd->bhd", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def masked_sparse_decode_attention(
    q: jax.Array,  # (b, hq, d)
    keys: jax.Array,  # (b, n, hkv, d)
    values: jax.Array,  # (b, n, hkv, d)
    mask: jax.Array,  # (b, hkv, n) bool — final pruned set (KV-head granular)
) -> jax.Array:
    """Definition 3.1 sparse attention: softmax restricted to the kept set.

    This is the static-shape TPU formulation: pruned tokens are masked, not
    gathered, so the semantics hold under any sharding; the Pallas kernel
    recovers the bandwidth win by skipping fully-masked pages.
    """
    b, n, hkv, d = keys.shape
    hq = q.shape[1]
    mask_q = jnp.repeat(mask, hq // hkv, axis=1)  # (b, hq, n)
    k = _expand_gqa(keys, hq)
    v = _expand_gqa(values, hq)
    scores = jnp.einsum("bhd,bnhd->bhn", q.astype(k.dtype), k,
                        preferred_element_type=jnp.float32)
    scores /= jnp.sqrt(jnp.asarray(d, jnp.float32))
    w = masked_softmax(scores, mask_q)
    out = jnp.einsum("bhn,bnhd->bhd", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def compact_decode_attention(
    q: jax.Array,  # (b, hq, d)
    k_gathered: jax.Array,  # (b, hkv, m, d) — candidate K rows
    v_gathered: jax.Array,  # (b, hkv, m, d) — candidate V rows
    valid: jax.Array,  # (b, hkv, m) bool — which slots are live
) -> jax.Array:
    """Attention over pre-gathered fixed-size candidate buffers.

    The hot compact path: everything here is O(m), never O(n).  Callers
    gather K/V (from the fp16 cache or the INT4 shadow cache) at the
    selector's candidate indices first.
    """
    b, hkv, m, d = k_gathered.shape
    hq = q.shape[1]
    group = hq // hkv
    qg = q.astype(jnp.float32).reshape(b, hkv, group, d)
    scores = jnp.einsum("bhgd,bhmd->bhgm", qg,
                        k_gathered.astype(jnp.float32)) / jnp.sqrt(
        jnp.asarray(d, jnp.float32)
    )
    w = masked_softmax(scores, valid[:, :, None, :])
    out = jnp.einsum("bhgm,bhmd->bhgd", w, v_gathered.astype(jnp.float32))
    return out.reshape(b, hq, d).astype(q.dtype)


def gather_kv_heads(x: jax.Array, indices: jax.Array) -> jax.Array:
    """Gather cache rows at per-KV-head positions (b, hkv, m) -> (b, hkv, m, c).

    Two cache layouts, distinguished by rank:

    * 4-D ``(b, n, hkv, c)`` — per-slot contiguous cache; indices are cache
      positions.
    * 3-D ``(P, hkv, c)`` — shared paged pool (P = num_pages * page_size);
      indices are *physical* pool rows (already translated through the page
      table by :func:`repro.core.selectors.physical_token_indices`).
    """
    if x.ndim == 3:
        pool = jnp.moveaxis(x, 1, 0)  # (hkv, P, c)
        return jax.vmap(
            lambda ib: jnp.take_along_axis(pool, ib[..., None], axis=1)
        )(indices)
    return jnp.take_along_axis(
        jnp.moveaxis(x, 2, 1), indices[..., None], axis=2)


def gather_quantized_kv_heads(
    indices: jax.Array,  # (b, hkv, m) i32 cache rows
    keys: jax.Array | None = None,  # fp cache, any gather_kv_heads layout
    qkeys: quant_lib.QuantizedTensor | None = None,  # INT4 shadow, same
) -> quant_lib.QuantizedTensor:
    """Stage the INT4 codes of a candidate buffer: (b, hkv, m, d//2)-packed.

    With a shadow cache, its packed/scale/zero rows are gathered; without
    one, the fp K rows are gathered and quantized on the fly.  The two are
    bit-identical because quantization is per-(token, head) row — the
    invariant both the staged estimate and the fused decode kernel rely
    on, kept in this one place.
    """
    if qkeys is not None:
        return quant_lib.QuantizedTensor(
            packed=gather_kv_heads(qkeys.packed, indices),
            scale=gather_kv_heads(qkeys.scale, indices),
            zero=gather_kv_heads(qkeys.zero, indices))
    if keys is None:
        raise ValueError("need keys or qkeys")
    return quant_lib.quantize_int4(gather_kv_heads(keys, indices))


def gathered_sparse_decode_attention(
    q: jax.Array,  # (b, hq, d)
    keys: jax.Array,  # (b, n, hkv, d)
    values: jax.Array,  # (b, n, hkv, d)
    indices: jax.Array,  # (b, hkv, m) i32 — gathered candidate positions
    valid: jax.Array,  # (b, hkv, m) bool — which slots are live
) -> jax.Array:
    """Budget-buffer formulation: attention over a fixed-size gathered subset.

    Equivalent to the masked form when (indices, valid) enumerate the mask;
    this is what the sparse_attn Pallas kernel computes after the pipeline
    compacts candidates into per-group index buffers.
    """
    return compact_decode_attention(
        q, gather_kv_heads(keys, indices), gather_kv_heads(values, indices),
        valid)


def mha_attention(
    q: jax.Array,  # (b, s, hq, d)
    keys: jax.Array,  # (b, n, hkv, d)
    values: jax.Array,  # (b, n, hkv, d)
    *,
    causal: bool = True,
    q_offset: jax.Array | int = 0,
    bias: jax.Array | None = None,
) -> jax.Array:
    """Batched multi-query attention for prefill/training (pure jnp)."""
    b, s, hq, d = q.shape
    n = keys.shape[1]
    k = _expand_gqa(keys, hq)
    v = _expand_gqa(values, hq)
    scores = jnp.einsum("bshd,bnhd->bhsn", q.astype(jnp.float32), k.astype(jnp.float32))
    scores /= jnp.sqrt(jnp.asarray(d, jnp.float32))
    if bias is not None:
        scores = scores + bias
    mask = None
    if causal:
        qpos = jnp.arange(s) + q_offset
        mask = (qpos[:, None] >= jnp.arange(n)[None, :])[None, None]
    w = masked_softmax(scores, mask)
    out = jnp.einsum("bhsn,bnhd->bshd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def attention_error(o_exact: jax.Array, o_sparse: jax.Array) -> jax.Array:
    """‖o − ô‖₂ per (batch, head) row — compared against (1−p)·‖V‖_F bounds."""
    diff = (o_exact.astype(jnp.float32) - o_sparse.astype(jnp.float32))
    return jnp.linalg.norm(diff, axis=-1)
