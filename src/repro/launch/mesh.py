"""Production mesh definitions (TPU v5e-like pods).

Functions, not module-level constants — importing this module never touches
jax device state, so tests/benches keep their single CPU device.
"""

from __future__ import annotations

import jax

# Hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over however many devices the current backend exposes."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
