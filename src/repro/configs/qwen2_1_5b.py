"""Qwen2-1.5B [arXiv:2407.10671] — dense GQA (kv=2) with QKV bias."""

from repro.core.twilight import TwilightConfig
from repro.models.common import ArchType, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b",
        arch_type=ArchType.DENSE,
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_ff=8960,
        vocab_size=151936,
        qkv_bias=True,
        rope_theta=1e6,
        tie_embeddings=True,
        twilight=TwilightConfig(selector="quest", p=0.95),
        citation="arXiv:2407.10671",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512,
        twilight=TwilightConfig(selector="quest", p=0.9, page_size=8,
                                min_candidate=16),
    )
