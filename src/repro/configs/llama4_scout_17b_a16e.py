"""Llama-4 Scout 17B-A16E [hf:meta-llama/Llama-4-Scout-17B-16E] — MoE 16
experts top-1 + shared expert, GQA kv=8."""

from repro.core.twilight import TwilightConfig
from repro.models.common import ArchType, MoEConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        arch_type=ArchType.MOE,
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=202048,
        rope_theta=5e5,
        moe=MoEConfig(n_experts=16, top_k=1, n_shared=1, d_expert=8192,
                      period=1),
        twilight=TwilightConfig(selector="quest", p=0.95),
        citation="hf:meta-llama/Llama-4-Scout-17B-16E",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_ff=128,
        vocab_size=512,
        moe=MoEConfig(n_experts=4, top_k=1, n_shared=1, d_expert=128, period=1),
        twilight=TwilightConfig(selector="quest", p=0.9, page_size=8,
                                min_candidate=16),
    )
