"""Survivor-run structure: RLE reference + jit-safe streaming telemetry.

The fused decode kernel streams the top-p survivor set from HBM as
page-aligned contiguous *runs* (``kernels/fused_decode``).  This module
makes the run structure observable:

* :func:`coalesced_runs` — the numpy reference run-length encoder the
  property tests pin the kernel's block coalescing against.  A run is a
  maximal stretch of kept slots whose logical indices are consecutive
  AND stay inside one ``page_size``-aligned page — exactly the units a
  physical-page pool can serve with one contiguous copy (the page table
  maps whole pages, so logical runs == physical runs).
* :func:`run_length_stats` — the jit-safe aggregate the paged decode step
  emits per layer when ``TwilightConfig.collect_run_stats`` is on: a
  fixed-size f32 vector (log2-bucketed run-length histogram, run count,
  pages touched, kept rows) that scans/sums cheaply through the model and
  the engine's session accumulators.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "RUN_HIST_BUCKETS",
    "RUN_STATS_LEN",
    "coalesced_runs",
    "prefill_page_stats",
    "run_length_stats",
    "summarize_run_stats",
]

# log2 histogram buckets: run length 1, 2-3, 4-7, ..., >= 2^(B-1).
RUN_HIST_BUCKETS = 8
# [hist(B) | n_runs | pages_touched | kept_rows
#  | live_page_hist(B) | cand_pages | cand_rows
#  | prefill_pages_live | prefill_pages_cand | prefill_qblocks]
# The second section is the hierarchical page-nucleus telemetry: a log2
# histogram of *live candidate pages per (batch, head) row* plus the summed
# live page / live slot counts — all zero when no candidate validity is
# supplied (flat pipeline), so legacy accumulators stay comparable.  The
# third section is the sparse-prefill twin (``prefill_page_stats``):
# surviving / candidate (query-block, kv-head, page) triples and the query
# block count, summed over chunks and layers — all zero when
# ``prefill_top_p`` is off.
RUN_STATS_LEN = 2 * RUN_HIST_BUCKETS + 8
# Offset of the prefill section inside the vector.
_PREFILL_BASE = 2 * RUN_HIST_BUCKETS + 5


def coalesced_runs(kept, indices, page_size: int) -> list[tuple[int, int]]:
    """Reference RLE of one kept row: ``[(start_slot, length), ...]``.

    ``kept`` (m,) bool over the candidate buffer, ``indices`` (m,) the
    ascending logical token indices of each slot.  A run breaks when the
    kept bit drops, when indices jump (non-consecutive tokens), or when a
    ``page_size`` boundary is crossed (``index % page_size == 0`` opens a
    new physical page).
    """
    kept = np.asarray(kept, bool)
    indices = np.asarray(indices)
    runs: list[tuple[int, int]] = []
    start = None
    for t in range(kept.shape[0]):
        if not kept[t]:
            start = None
            continue
        fresh = (start is None
                 or indices[t] != indices[t - 1] + 1
                 or indices[t] % page_size == 0)
        if fresh:
            start = t
            runs.append((t, 1))
        else:
            s, ln = runs[-1]
            runs[-1] = (s, ln + 1)
    return runs


def run_length_stats(kept: jax.Array, indices: jax.Array, page_size: int,
                     n_pages: int,
                     cand_valid: jax.Array | None = None) -> jax.Array:
    """Aggregate run structure of a batch of kept rows, jit-safe.

    ``kept``/``indices`` are (..., m) — typically (b, hkv, m) from one
    attention layer's pipeline output (``pruned_valid``/``indices``).
    Returns the (RUN_STATS_LEN,) f32 vector
    ``[hist_0..hist_{B-1}, n_runs, pages_touched, kept_rows,
    live_hist_0..live_hist_{B-1}, cand_pages, cand_rows]`` summed over
    every leading dim; vectors from different layers/steps add.
    ``n_pages`` bounds ``indices // page_size`` (logical pages per slot).

    ``cand_valid`` (same shape as ``kept``) marks the live *candidate*
    slots the pruner saw — under the hierarchical page nucleus this is the
    adaptive page-survivor set, so the second section histograms how many
    candidate pages actually survived per row (the ``--run-stats``
    live-pages histogram).  ``None`` leaves the section zero.
    """
    kept = kept.astype(bool)
    m = kept.shape[-1]
    # Run starts: kept, and not a contiguous same-page continuation.
    prev_kept = jnp.pad(kept[..., :-1], [(0, 0)] * (kept.ndim - 1) + [(1, 0)])
    prev_idx = jnp.pad(indices[..., :-1],
                       [(0, 0)] * (kept.ndim - 1) + [(1, 0)],
                       constant_values=-2)
    cont = (prev_kept & (indices == prev_idx + 1)
            & (indices % page_size != 0))
    starts = kept & ~cont
    nxt_kept = jnp.pad(kept[..., 1:], [(0, 0)] * (kept.ndim - 1) + [(0, 1)])
    nxt_idx = jnp.pad(indices[..., 1:],
                      [(0, 0)] * (kept.ndim - 1) + [(0, 1)],
                      constant_values=-2)
    ends = kept & ~(nxt_kept & (nxt_idx == indices + 1)
                    & (nxt_idx % page_size != 0))

    t = jnp.arange(m, dtype=jnp.int32)
    start_pos = jax.lax.cummax(jnp.where(starts, t, -1), axis=kept.ndim - 1)
    lengths = jnp.where(ends, t - start_pos + 1, 0)  # length at run end

    bucket = jnp.clip(
        jnp.floor(jnp.log2(jnp.maximum(lengths, 1).astype(jnp.float32))),
        0, RUN_HIST_BUCKETS - 1).astype(jnp.int32)
    hist = jnp.sum(
        jax.nn.one_hot(bucket, RUN_HIST_BUCKETS, dtype=jnp.float32)
        * ends[..., None].astype(jnp.float32),
        axis=tuple(range(ends.ndim)))

    pages = jnp.clip(indices // page_size, 0, n_pages - 1)
    flat_pages = pages.reshape(-1, m)
    flat_kept = kept.reshape(-1, m)

    def _touched(flat_bits):
        grid = jnp.zeros((flat_pages.shape[0], n_pages), jnp.float32)
        return grid.at[
            jnp.arange(flat_pages.shape[0])[:, None], flat_pages].max(
            flat_bits.astype(jnp.float32))

    touched = _touched(flat_kept)

    if cand_valid is None:
        live_hist = jnp.zeros((RUN_HIST_BUCKETS,), jnp.float32)
        cand_pages = jnp.zeros((), jnp.float32)
        cand_rows = jnp.zeros((), jnp.float32)
    else:
        cand_valid = cand_valid.astype(bool)
        live = _touched(cand_valid.reshape(-1, m))  # (rows, n_pages) 0/1
        live_per_row = live.sum(axis=-1)  # live candidate pages per row
        live_bucket = jnp.clip(
            jnp.floor(jnp.log2(jnp.maximum(live_per_row, 1.0))),
            0, RUN_HIST_BUCKETS - 1).astype(jnp.int32)
        live_hist = jnp.sum(
            jax.nn.one_hot(live_bucket, RUN_HIST_BUCKETS, dtype=jnp.float32),
            axis=0)
        cand_pages = jnp.sum(live)
        cand_rows = jnp.sum(cand_valid).astype(jnp.float32)

    return jnp.concatenate([
        hist,
        jnp.sum(starts).astype(jnp.float32)[None],
        jnp.sum(touched)[None],
        jnp.sum(kept).astype(jnp.float32)[None],
        live_hist,
        cand_pages[None],
        cand_rows[None],
        jnp.zeros((3,), jnp.float32),  # prefill section (decode emits none)
    ])


def prefill_page_stats(survivors: jax.Array,
                       participate: jax.Array) -> jax.Array:
    """Sparse-prefill live-page telemetry as a (RUN_STATS_LEN,) vector.

    ``survivors``/``participate`` are the (b, nqb, hkv, n_pages) bool masks
    ``sparse_prefill_attend`` returns as aux: surviving vs causally visible
    pages per (query block, kv head).  Only the prefill slots are set, so
    the vector adds directly into the same session accumulator as the
    decode :func:`run_length_stats` vectors.
    """
    live = jnp.sum(survivors & participate).astype(jnp.float32)
    cand = jnp.sum(participate).astype(jnp.float32)
    qblocks = jnp.asarray(
        survivors.shape[0] * survivors.shape[1], jnp.float32)
    vec = jnp.zeros((RUN_STATS_LEN,), jnp.float32)
    return vec.at[_PREFILL_BASE:].set(jnp.stack([live, cand, qblocks]))


def summarize_run_stats(total: np.ndarray, steps: int) -> dict:
    """Human-readable summary of summed :func:`run_length_stats` vectors."""
    total = np.asarray(total, np.float64)
    hist = total[:RUN_HIST_BUCKETS]
    n_runs, pages, kept = total[RUN_HIST_BUCKETS:RUN_HIST_BUCKETS + 3]
    live_hist = total[RUN_HIST_BUCKETS + 3:2 * RUN_HIST_BUCKETS + 3]
    cand_pages, cand_rows = total[2 * RUN_HIST_BUCKETS + 3:_PREFILL_BASE]
    pf_live, pf_cand, pf_qblocks = total[_PREFILL_BASE:]
    steps = max(steps, 1)
    return {
        "steps": int(steps),
        "run_hist": [int(x) for x in hist],
        "runs_per_step": n_runs / steps,
        "pages_per_step": pages / steps,
        "kept_per_step": kept / steps,
        "mean_run_len": kept / max(n_runs, 1.0),
        # Hierarchical page-nucleus telemetry (all zero on flat pipelines).
        "live_page_hist": [int(x) for x in live_hist],
        "cand_pages_per_step": cand_pages / steps,
        "cand_rows_per_step": cand_rows / steps,
        # Sparse-prefill live-page telemetry (zero when prefill_top_p off).
        "prefill_pages_live": pf_live,
        "prefill_pages_cand": pf_cand,
        "prefill_qblocks": pf_qblocks,
        "prefill_live_frac": pf_live / max(pf_cand, 1.0),
    }
