"""The Twilight Pruner (§4.1–4.2): re-estimate attention weights on the
candidate set with an INT4-quantized K cache, then keep only the top-p subset.

GQA semantics (Appendix B.2): weights and top-p masks are computed per *query*
head; the pruned set actually loaded for a KV head is the union over its
group, so budgets are group-wise under GQA and head-wise under MHA.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import quant as quant_lib
from repro.core import topp as topp_lib
from repro.core.selectors import group_union

__all__ = ["PrunerStats", "TwilightPruner"]


class PrunerStats(NamedTuple):
    candidate_budget: jax.Array  # i32 (b, hkv) — |I0| per group
    pruned_budget: jax.Array  # i32 (b, hkv) — |I1| per group after top-p
    threshold: jax.Array  # f32 (b, hq) — applied weight threshold
    weights: jax.Array  # f32 (b, hq, n) — estimated normalized weights


@dataclasses.dataclass(frozen=True)
class TwilightPruner:
    """Top-p pruning over selector candidates.

    Args:
      p: cumulative-weight threshold (paper uses 0.95 LLaMA, 0.85 Longchat).
      iters: binary-search iterations (Algorithm 1).
      estimate_bits: 4 (paper sweet spot), 8, or 16 (= no quantization) for
        the score-estimation K cache.  Fig. 6 ablation is reproduced by
        sweeping this.
    """

    p: float = 0.95
    iters: int = 24
    estimate_bits: int = 4

    def estimate_scores(
        self,
        q: jax.Array,  # (b, hq, d)
        keys: jax.Array | None,  # (b, n, hkv, d) fp K (estimate_bits >= 16)
        qkeys: quant_lib.QuantizedTensor | None,  # INT4 shadow cache
    ) -> jax.Array:
        """q·K̃ / sqrt(d) per query head: (b, hq, n)."""
        if self.estimate_bits <= 4:
            if qkeys is None:
                if keys is None:
                    raise ValueError("need keys or qkeys")
                qkeys = quant_lib.quantize_int4(keys)
            # bf16 is exact enough for 4-bit codes and halves the
            # materialized estimate buffer (the Pallas spgemv kernel never
            # materializes it at all — this is the jnp fallback).
            k_est = quant_lib.dequantize_int4(qkeys, dtype=jnp.bfloat16)
        else:
            if keys is None:
                raise ValueError("need full-precision keys")
            k_est = keys
        b, n, hkv, d = k_est.shape
        hq = q.shape[1]
        group = hq // hkv
        qg = q.reshape(b, hkv, group, d).astype(k_est.dtype)
        scores = jnp.einsum("bhgd,bnhd->bhgn", qg, k_est,
                            preferred_element_type=jnp.float32)
        return scores.reshape(b, hq, n) / jnp.sqrt(jnp.asarray(d, jnp.float32))

    def prune(
        self,
        q: jax.Array,  # (b, hq, d)
        candidate_mask: jax.Array,  # (b, hkv, n) from the Token Selector
        *,
        keys: jax.Array | None = None,
        qkeys: quant_lib.QuantizedTensor | None = None,
        p: jax.Array | float | None = None,
    ) -> tuple[jax.Array, PrunerStats]:
        """Returns the pruned KV-head mask (b, hkv, n) and stats."""
        b, hkv, n = candidate_mask.shape
        hq = q.shape[1]
        group = hq // hkv
        p_val = self.p if p is None else p

        scores = self.estimate_scores(q, keys, qkeys)  # (b, hq, n)
        cand_q = jnp.repeat(candidate_mask, group, axis=1)  # (b, hq, n)
        weights = topp_lib.masked_softmax(scores, cand_q)  # normalized (C1: needs softmax)
        res = topp_lib.topp_mask(weights, p_val, iters=self.iters)
        pruned_q = res.mask & cand_q
        pruned_kv = group_union(pruned_q, hkv)  # (b, hkv, n)
        stats = PrunerStats(
            candidate_budget=candidate_mask.sum(-1).astype(jnp.int32),
            pruned_budget=pruned_kv.sum(-1).astype(jnp.int32),
            threshold=res.threshold,
            weights=weights,
        )
        return pruned_kv, stats
