"""Batched serving with the Twilight engine: a wave of mixed-length
requests through prefill + continuous decode, with per-request pruned-budget
telemetry.  Works for any assigned architecture (pass --arch).

    PYTHONPATH=src python examples/serve_batch.py --arch deepseek-moe-16b
    PYTHONPATH=src python examples/serve_batch.py --arch internvl2-1b
"""

import argparse

import numpy as np

from repro.configs import get_smoke_config
from repro.serving import DecodeEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    rng = np.random.default_rng(0)
    engine = DecodeEngine(cfg, batch_size=3, cache_capacity=128)

    reqs = []
    for uid in range(args.requests):
        extras = {}
        if cfg.frontend == "audio":
            extras["frames"] = rng.normal(size=(48, cfg.d_model)).astype(
                np.float32)
        elif cfg.frontend == "vision":
            extras["patches"] = rng.normal(
                size=(cfg.n_prefix_tokens, cfg.d_model)).astype(np.float32)
        prompt_len = int(rng.integers(24, 72))
        reqs.append(Request(
            uid=uid,
            prompt=rng.integers(8, cfg.vocab_size, prompt_len).astype(np.int32),
            max_new_tokens=args.max_new,
            extras=extras or None,
        ))

    results = engine.generate(reqs)
    for r in sorted(results, key=lambda r: r.uid):
        print(f"req {r.uid}: prompt={r.prompt_len:3d} tok, "
              f"generated={r.tokens}, "
              f"mean pruned budget={r.mean_pruned_budget:.1f}")


if __name__ == "__main__":
    main()
