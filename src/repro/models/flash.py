"""Memory-efficient (flash) attention for the train/prefill path.

Pure-JAX blockwise attention with a custom VJP: the forward stores only
(o, logsumexp) — O(s·d) residuals instead of the O(s²) score matrix — and
the backward recomputes per-block scores.  This is the XLA-level analogue
of FlashAttention-2 [39]; the Pallas decode kernel covers the single-query
path, this covers full sequences.

GQA is handled natively: scores are computed per KV head against the whole
query group, and dk/dv sum over the group.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _blockify(x: jax.Array, block: int) -> jax.Array:
    """(b, s, h, d) -> (nb, b, block, h, d)."""
    b, s, h, d = x.shape
    return jnp.moveaxis(x.reshape(b, s // block, block, h, d), 1, 0)


def _choose_block(s: int, q_block: int) -> int:
    """Query tile size: the preferred block, capped at the sequence.

    Ragged sequences are padded up to a block multiple and the tail rows
    sliced away — the block size never degrades to tiny divisors (the old
    ``while s % q_block: q_block -= 1`` collapsed to 1 for prime lengths
    like 8191, serializing the whole scan).
    """
    return max(1, min(q_block, s))


def _pad_rows(x: jax.Array, pad: int, axis: int = 1,
              value: float = 0.0) -> jax.Array:
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _scores(qb, k, hkv, sm_scale):
    """qb: (b, blk, hq, d), k: (b, n, hkv, d) -> (b, hkv, g, blk, n) f32."""
    b, blk, hq, d = qb.shape
    g = hq // hkv
    qg = qb.reshape(b, blk, hkv, g, d).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bnhd->bhgqn", qg, k.astype(jnp.float32))
    return s * sm_scale


def _causal_mask(blk_idx, block, n, q_offset):
    qpos = blk_idx * block + jnp.arange(block) + q_offset
    return qpos[:, None] >= jnp.arange(n)[None, :]  # (block, n)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, q_block: int = 512,
                    q_offset: int = 0) -> jax.Array:
    """q: (b, s, hq, d), k/v: (b, n, hkv, d) -> (b, s, hq, d)."""
    o, _ = _flash_fwd_impl(q, k, v, causal, q_block, q_offset)
    return o


def _flash_fwd_impl(q, k, v, causal, q_block, q_offset):
    b, s, hq, d = q.shape
    n, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    q_block = _choose_block(s, q_block)
    pad = (-s) % q_block
    sp = s + pad
    sm_scale = d ** -0.5
    qb_all = _blockify(_pad_rows(q, pad), q_block)  # (nb, b, blk, hq, d)

    def one_block(blk_idx, qb):
        sc = _scores(qb, k, hkv, sm_scale)  # (b, hkv, g, blk, n)
        if causal:
            m = _causal_mask(blk_idx, q_block, n, q_offset)
            sc = jnp.where(m[None, None, None], sc, NEG_INF)
        mx = jnp.max(sc, axis=-1, keepdims=True)
        p = jnp.exp(sc - mx)
        l = jnp.sum(p, axis=-1, keepdims=True)
        lse = (mx + jnp.log(jnp.maximum(l, 1e-30)))[..., 0]  # (b,hkv,g,blk)
        ob = jnp.einsum("bhgqn,bnhd->bhgqd", p / jnp.maximum(l, 1e-30),
                        v.astype(jnp.float32))
        return ob, lse

    def scan_body(_, inp):
        blk_idx, qb = inp
        return None, one_block(blk_idx, qb)

    nb = sp // q_block
    _, (ob, lse) = jax.lax.scan(
        scan_body, None, (jnp.arange(nb), qb_all))
    # ob: (nb, b, hkv, g, blk, d) -> (b, s, hq, d); pad rows sliced away.
    o = jnp.moveaxis(ob, 0, 3)  # (b, hkv, g, nb, blk, d)
    o = o.reshape(b, hkv, g, sp, d)
    o = jnp.moveaxis(o.reshape(b, hq, sp, d), 1, 2).astype(q.dtype)
    o = o[:, :s]
    lse = jnp.moveaxis(lse, 0, 3).reshape(b, hkv, g, sp)[..., :s]
    return o, lse


def _flash_fwd(q, k, v, causal, q_block, q_offset):
    o, lse = _flash_fwd_impl(q, k, v, causal, q_block, q_offset)
    return o, (q, k, v, o, lse)


def _flash_bwd(causal, q_block, q_offset, res, do):
    q, k, v, o, lse = res
    b, s, hq, d = q.shape
    n, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    q_block = _choose_block(s, q_block)
    pad = (-s) % q_block
    sp = s + pad
    sm_scale = d ** -0.5
    nb = sp // q_block

    qb_all = _blockify(_pad_rows(q, pad), q_block)
    do_all = _blockify(_pad_rows(do.astype(jnp.float32), pad), q_block)
    o_all = _blockify(_pad_rows(o.astype(jnp.float32), pad), q_block)
    # lse (b, hkv, g, s) -> (nb, b, hkv, g, blk).  Pad rows carry +inf so
    # p = exp(sc - inf) = 0 exactly: they contribute nothing to dk/dv and
    # their dq rows (sliced below) stay finite.
    lse_all = jnp.moveaxis(
        _pad_rows(lse, pad, axis=3, value=jnp.inf
                  ).reshape(b, hkv, g, nb, q_block), 3, 0)

    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def scan_body(carry, inp):
        dk, dv = carry
        blk_idx, qb, dob, ob, lseb = inp
        sc = _scores(qb, k, hkv, sm_scale)  # (b,hkv,g,blk,n)
        if causal:
            m = _causal_mask(blk_idx, q_block, n, q_offset)
            sc = jnp.where(m[None, None, None], sc, NEG_INF)
        p = jnp.exp(sc - lseb[..., None])  # (b,hkv,g,blk,n)
        dog = jnp.moveaxis(dob.reshape(b, q_block, hkv, g, d), 1, 3)
        og = jnp.moveaxis(ob.reshape(b, q_block, hkv, g, d), 1, 3)
        dp = jnp.einsum("bhgqd,bnhd->bhgqn", dog, vf)
        delta = jnp.sum(dog * og, axis=-1, keepdims=True)  # (b,hkv,g,blk,1)
        ds = p * (dp - delta) * sm_scale
        dqb = jnp.einsum("bhgqn,bnhd->bhgqd", ds, kf)
        dqb = jnp.moveaxis(dqb, 3, 1).reshape(b, q_block, hq, d)
        dk = dk + jnp.einsum("bhgqn,bhgqd->bnhd", ds,
                             jnp.moveaxis(qb.reshape(
                                 b, q_block, hkv, g, d), 1, 3).astype(jnp.float32))
        dv = dv + jnp.einsum("bhgqn,bhgqd->bnhd", p, dog)
        return (dk, dv), dqb

    dk0 = jnp.zeros((b, n, hkv, d), jnp.float32)
    dv0 = jnp.zeros((b, n, hkv, d), jnp.float32)
    (dk, dv), dq_blocks = jax.lax.scan(
        scan_body, (dk0, dv0),
        (jnp.arange(nb), qb_all, do_all, o_all, lse_all))
    dq = jnp.moveaxis(dq_blocks, 0, 1).reshape(b, sp, hq, d)[:, :s]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_flash_fwd, _flash_bwd)
