"""AdamW with decoupled weight decay and global-norm clipping.

Optimizer state mirrors the param tree (m, v in f32 regardless of the
param dtype — the usual mixed-precision recipe), so it inherits the params'
sharding under pjit.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Tree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def adamw_init(params: Tree) -> Tree:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(cfg: AdamWConfig, grads: Tree, state: Tree, params: Tree,
                 lr: jax.Array) -> tuple[Tree, Tree, dict[str, jax.Array]]:
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state["step"] + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
