"""Shared test fixtures.  NOTE: no XLA_FLAGS here — tests run on the single
CPU device; only launch/dryrun.py requests 512 placeholder devices."""

import os
import sys

# Tests import helpers as `tests.conftest` and benchmarks as `benchmarks.*`;
# make the repo root importable regardless of how pytest was invoked.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def make_weights(rng, rows, n, concentration=3.0):
    """Random normalized attention-weight rows."""
    logits = rng.normal(size=(rows, n)) * concentration
    w = np.exp(logits - logits.max(-1, keepdims=True))
    return (w / w.sum(-1, keepdims=True)).astype(np.float32)
