"""Model zoo: every assigned architecture family, pure JAX.

Families: dense GQA transformer, fine-grained MoE, Mamba/attention hybrid
(Jamba), xLSTM (sLSTM+mLSTM), encoder-decoder (Seamless), and multimodal
backbones (audio/VLM) consuming stub frontend embeddings.

The public entry points are in :mod:`repro.models.model`:

* ``init_params(cfg, key)``
* ``forward(params, cfg, batch)``            — teacher-forcing logits
* ``init_decode_state(cfg, batch, max_len)`` — caches for serving
* ``decode_step(params, cfg, state, token)`` — one token w/ Twilight
"""

from repro.models.common import (
    ArchType,
    MoEConfig,
    ModelConfig,
    SSMConfig,
    XLSTMConfig,
    block_pattern,
)
from repro.models.model import (
    copy_page,
    count_params,
    decode_step,
    decode_step_paged,
    decode_window_paged,
    forward,
    init_decode_state,
    init_paged_decode_state,
    init_params,
    prefill,
    prefill_chunk,
    supports_chunked_prefill,
    write_prefill_slot,
)

__all__ = [
    "ArchType",
    "MoEConfig",
    "ModelConfig",
    "SSMConfig",
    "XLSTMConfig",
    "block_pattern",
    "copy_page",
    "count_params",
    "decode_step",
    "decode_step_paged",
    "decode_window_paged",
    "forward",
    "init_decode_state",
    "init_paged_decode_state",
    "init_params",
    "prefill",
    "prefill_chunk",
    "supports_chunked_prefill",
    "write_prefill_slot",
]
