"""Paged KV-cache pool + continuous batching vs the contiguous oracle.

Three levels of equivalence, mirroring how the feature is layered:

* core — ``twilight_decode_attention`` over a shuffled page pool + page
  tables must match the contiguous cache bit-for-bit (fp32 allclose) for
  every selector, including ragged lengths;
* model — ``decode_step_paged`` logits must match per-request contiguous
  ``prefill``/``decode_step`` at ragged lengths sharing one batch;
* engine — continuous batching must emit exactly the tokens the
  per-request contiguous oracle emits (greedy), including under a tight
  pool that forces recompute preemption.

Plus: allocator alloc/free/fragmentation invariants, per-slot sampling
modes in one wave, and the spgemv-routed compact estimate.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import (
    PageMeta,
    SelectionContext,
    TwilightConfig,
    build_page_meta,
    calibrate_ds_channels,
    quantize_int4,
    twilight_decode_attention,
)
from repro.core.pruner import TwilightPruner
from repro.serving import DecodeEngine, Request
from repro.serving.paged_cache import NULL_PAGE, PageAllocator, pages_for

SELECTORS = ("full", "quest", "double_sparsity", "streaming", "h2o")


# ---------------------------------------------------------------------------
# Allocator invariants
# ---------------------------------------------------------------------------

def test_allocator_invariants():
    alloc = PageAllocator(9)
    assert alloc.capacity == 8 and alloc.available == 8
    a = alloc.alloc(3)
    b = alloc.alloc(2)
    assert len(set(a) | set(b)) == 5, "no page handed out twice"
    assert NULL_PAGE not in a + b, "null page is reserved"
    assert all(0 < p < 9 for p in a + b)
    assert alloc.available == 3
    assert alloc.available + len(alloc.allocated) == alloc.capacity
    alloc.free(a)
    assert alloc.available == 6
    assert set(alloc.allocated) == set(b)


def test_allocator_exhaustion_and_reuse():
    alloc = PageAllocator(5)
    a = alloc.alloc(4)
    with pytest.raises(MemoryError):
        alloc.alloc(1)
    alloc.free(a[:2])
    b = alloc.alloc(2)
    assert set(b) == set(a[:2]), "freed pages are recycled"
    assert alloc.available == 0


def test_allocator_fragmentation_cycles():
    """Interleaved alloc/free cycles keep accounting exact and never leak."""
    rng = np.random.default_rng(0)
    alloc = PageAllocator(33)
    held: list[list[int]] = []
    for _ in range(200):
        if held and (alloc.available == 0 or rng.random() < 0.4):
            alloc.free(held.pop(int(rng.integers(len(held)))))
        else:
            n = int(rng.integers(1, min(5, alloc.available) + 1))
            held.append(alloc.alloc(n))
        flat = [p for h in held for p in h]
        assert len(flat) == len(set(flat)), "double allocation"
        assert alloc.available + len(flat) == alloc.capacity
    for h in held:
        alloc.free(h)
    assert alloc.available == alloc.capacity


def test_allocator_double_free_rejected():
    alloc = PageAllocator(4)
    a = alloc.alloc(2)
    alloc.free(a)
    with pytest.raises(ValueError):
        alloc.free([a[0]])
    with pytest.raises(ValueError):
        alloc.free([NULL_PAGE])


# ---------------------------------------------------------------------------
# Core: paged pipeline == contiguous pipeline
# ---------------------------------------------------------------------------

def _paged_fixture(rng, b=2, hq=8, hkv=2, n=256, d=64, ps=16):
    """Contiguous (q, K, V, ctx, qkeys) plus a pool holding the same data at
    *shuffled* physical pages behind per-slot page tables."""
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    K = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    acc = jnp.asarray(rng.random((b, hkv, n)), jnp.float32)
    ds = calibrate_ds_channels(K, 8)
    pm = build_page_meta(K, ps)

    n_pages = n // ps
    num_pages = 1 + b * n_pages + 3  # null + slack
    perm = rng.permutation(np.arange(1, num_pages))
    pt = np.zeros((b, n_pages), np.int32)
    rows = num_pages * ps
    # Pool starts as junk everywhere (incl. the null page) so any gather
    # that escapes the page table would be caught by the equivalence check.
    k_pool = np.asarray(rng.normal(size=(rows, hkv, d)), np.float32)
    v_pool = np.asarray(rng.normal(size=(rows, hkv, d)), np.float32)
    pmax_pool = np.asarray(rng.normal(size=(num_pages, hkv, d)), np.float32)
    pmin_pool = np.asarray(rng.normal(size=(num_pages, hkv, d)), np.float32)
    Knp, Vnp = np.asarray(K), np.asarray(V)
    kmax, kmin = np.asarray(pm.kmax), np.asarray(pm.kmin)
    i = 0
    for bb in range(b):
        for p in range(n_pages):
            phys = int(perm[i])
            i += 1
            pt[bb, p] = phys
            k_pool[phys * ps:(phys + 1) * ps] = Knp[bb, p * ps:(p + 1) * ps]
            v_pool[phys * ps:(phys + 1) * ps] = Vnp[bb, p * ps:(p + 1) * ps]
            pmax_pool[phys] = kmax[bb, p]
            pmin_pool[phys] = kmin[bb, p]
    k_pool, v_pool = jnp.asarray(k_pool), jnp.asarray(v_pool)
    pm_pool = PageMeta(kmax=jnp.asarray(pmax_pool), kmin=jnp.asarray(pmin_pool),
                       page_size=ps)
    return {
        "q": q, "K": K, "V": V, "qkeys": quantize_int4(K),
        "ctx": lambda length: SelectionContext(
            keys=K, page_meta=pm, accum_scores=acc, length=length,
            ds_channels=ds),
        "k_pool": k_pool, "v_pool": v_pool,
        "qkeys_pool": quantize_int4(k_pool),
        "ctx_paged": lambda length: SelectionContext(
            keys=k_pool, page_meta=pm_pool, accum_scores=acc, length=length,
            ds_channels=ds, page_table=jnp.asarray(pt)),
    }


@pytest.mark.parametrize("selector", SELECTORS)
@pytest.mark.parametrize("ragged", [False, True])
def test_paged_pipeline_matches_contiguous(rng, selector, ragged):
    fx = _paged_fixture(rng)
    length = jnp.asarray([256, 180]) if ragged else jnp.asarray([256, 256])
    cfg = TwilightConfig(selector=selector, p=0.9, candidate_frac=0.5,
                         page_size=16, min_candidate=64)
    ref = twilight_decode_attention(
        fx["q"], fx["K"], fx["V"], cfg, ctx=fx["ctx"](length),
        qkeys=fx["qkeys"], length=length)
    paged = twilight_decode_attention(
        fx["q"], fx["k_pool"], fx["v_pool"], cfg, ctx=fx["ctx_paged"](length),
        qkeys=fx["qkeys_pool"], length=length)
    np.testing.assert_allclose(np.asarray(paged.out), np.asarray(ref.out),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(paged.stats.candidate_budget),
                                  np.asarray(ref.stats.candidate_budget))
    np.testing.assert_array_equal(np.asarray(paged.stats.pruned_budget),
                                  np.asarray(ref.stats.pruned_budget))
    # Same logical candidate sets: the paged selector emits logical indices.
    np.testing.assert_array_equal(np.asarray(paged.indices),
                                  np.asarray(ref.indices))


def test_paged_pipeline_with_pruned_cap(rng):
    """The B1 re-compaction path translates through the page table too."""
    fx = _paged_fixture(rng)
    length = jnp.asarray([256, 200])
    cfg = TwilightConfig(selector="quest", p=0.999, candidate_frac=1.0,
                         page_size=16, pruned_cap_frac=0.25)
    ref = twilight_decode_attention(
        fx["q"], fx["K"], fx["V"], cfg, ctx=fx["ctx"](length),
        qkeys=fx["qkeys"], length=length)
    paged = twilight_decode_attention(
        fx["q"], fx["k_pool"], fx["v_pool"], cfg, ctx=fx["ctx_paged"](length),
        qkeys=fx["qkeys_pool"], length=length)
    np.testing.assert_allclose(np.asarray(paged.out), np.asarray(ref.out),
                               rtol=1e-5, atol=1e-5)


def test_paged_requires_compact():
    rng = np.random.default_rng(0)
    fx = _paged_fixture(rng)
    length = jnp.asarray([256, 256])
    cfg = TwilightConfig(selector="quest", compact=False, page_size=16)
    with pytest.raises(ValueError, match="compact"):
        twilight_decode_attention(
            fx["q"], fx["k_pool"], fx["v_pool"], cfg,
            ctx=fx["ctx_paged"](length), qkeys=fx["qkeys_pool"],
            length=length)


# ---------------------------------------------------------------------------
# Model: paged decode == contiguous decode at ragged lengths
# ---------------------------------------------------------------------------

def test_model_paged_decode_matches_contiguous(rng):
    from repro.models import (decode_step, decode_step_paged,
                              init_paged_decode_state, init_params, prefill,
                              write_prefill_slot)
    cfg = get_smoke_config("qwen2-1.5b")
    ps = cfg.twilight.page_size
    capacity = 64
    max_pages = capacity // ps
    import jax
    params = init_params(cfg, jax.random.PRNGKey(7))
    prompts = [rng.integers(8, cfg.vocab_size, L).astype(np.int32)
               for L in (24, 13)]
    steps = [rng.integers(8, cfg.vocab_size, 3).astype(np.int32)
             for _ in prompts]

    oracle = []
    for pr, ts in zip(prompts, steps):
        lg, st = prefill(params, cfg, {"tokens": jnp.asarray(pr[None])},
                         n_max=capacity)
        outs = [np.asarray(lg[0, len(pr) - 1, :cfg.vocab_size], np.float32)]
        for t in ts:
            lg2, st, _ = decode_step(params, cfg, st, jnp.asarray([t]))
            outs.append(np.asarray(lg2[0, :cfg.vocab_size], np.float32))
        oracle.append(outs)

    b = 2
    alloc = PageAllocator(1 + b * max_pages)
    state = init_paged_decode_state(cfg, b, alloc.num_pages)
    pt = np.zeros((b, max_pages), np.int32)
    lengths = np.zeros((b,), np.int32)
    paged = [[], []]
    for s, pr in enumerate(prompts):
        n_req = pages_for(len(pr), ps)
        pages = alloc.alloc(n_req)
        lg, pstate = prefill(params, cfg, {"tokens": jnp.asarray(pr[None])},
                             n_max=n_req * ps)
        state = write_prefill_slot(cfg, state, pstate, s, jnp.asarray(pages))
        pt[s, :n_req] = pages
        lengths[s] = len(pr)
        paged[s].append(
            np.asarray(lg[0, len(pr) - 1, :cfg.vocab_size], np.float32))

    live = np.ones((b,), bool)
    for i in range(3):
        for s in range(b):
            if lengths[s] % ps == 0:
                pt[s, lengths[s] // ps] = alloc.alloc(1)[0]
        tok = jnp.asarray([steps[0][i], steps[1][i]])
        lg, state, stats = decode_step_paged(
            params, cfg, state, tok, jnp.asarray(pt), jnp.asarray(lengths),
            jnp.asarray(live))
        assert stats["pruned_budget"].shape == (b,)
        for s in range(b):
            paged[s].append(np.asarray(lg[s, :cfg.vocab_size], np.float32))
        lengths += 1

    for s in range(b):
        for i, (ref, got) in enumerate(zip(oracle[s], paged[s])):
            np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4,
                                       err_msg=f"slot {s} step {i}")


# ---------------------------------------------------------------------------
# Engine: continuous batching == per-request contiguous oracle
# ---------------------------------------------------------------------------

def _requests(rng, cfg, shapes):
    return [Request(uid=uid,
                    prompt=rng.integers(8, cfg.vocab_size, L
                                        ).astype(np.int32),
                    max_new_tokens=mn)
            for uid, (L, mn) in enumerate(shapes)]


def test_engine_continuous_matches_oracle(rng):
    cfg = get_smoke_config("qwen2-1.5b")
    reqs = _requests(rng, cfg, [(24, 5), (17, 3), (9, 1)])
    # batch_size=1 waves serve each request alone — the padding-free oracle
    # (ragged waves left-pad, which shifts RoPE positions and changes the
    # answer; continuous batching is padding-free by construction).
    solo = DecodeEngine(cfg, batch_size=1, cache_capacity=64, seed=7)
    paged = DecodeEngine(cfg, params=solo.params, batch_size=2,
                         cache_capacity=64, seed=7, paged=True)
    want = {r.uid: r.tokens for r in solo.generate(reqs)}
    got = {r.uid: r.tokens for r in paged.generate(reqs)}
    assert got == want
    for r in paged.generate(reqs[:1]):
        assert r.decode_steps == 5 and len(r.tokens) == 5


def test_engine_tight_pool_preemption(rng):
    """A pool far below worst case forces recompute preemption; tokens must
    still match the oracle exactly.

    Sizing: two 17-token prompts (3 pages each) decoding 20 tokens each in
    a 8-allocatable-page pool — both admit (worst case 5 pages each), then
    both cross page boundaries twice, exhausting the pool mid-decode.
    """
    cfg = get_smoke_config("qwen2-1.5b")
    reqs = _requests(rng, cfg, [(17, 20), (17, 20)])
    solo = DecodeEngine(cfg, batch_size=1, cache_capacity=40, seed=7)
    tight = DecodeEngine(cfg, params=solo.params, batch_size=2,
                         cache_capacity=40, seed=7, paged=True, num_pages=9)
    want = {r.uid: r.tokens for r in solo.generate(reqs)}
    got = {r.uid: r.tokens for r in tight.generate(reqs)}
    assert tight.last_preemptions > 0, "pool sizing must force preemption"
    assert got == want


def test_engine_rejects_oversized_request(rng):
    cfg = get_smoke_config("qwen2-1.5b")
    ps = cfg.twilight.page_size
    engine = DecodeEngine(cfg, batch_size=2, cache_capacity=64, seed=0,
                          paged=True, num_pages=3)
    reqs = _requests(rng, cfg, [(40, 8)])
    with pytest.raises(ValueError, match="num_pages"):
        engine.generate(reqs)


def test_wave_per_slot_sampling(rng):
    """A greedy and a sampling request share one wave; the greedy slot's
    tokens must be exactly its solo-greedy continuation (previously the
    engine collapsed the wave to all(r.greedy))."""
    cfg = get_smoke_config("qwen2-1.5b")
    p0 = rng.integers(8, cfg.vocab_size, 24).astype(np.int32)
    p1 = rng.integers(8, cfg.vocab_size, 24).astype(np.int32)
    eng = DecodeEngine(cfg, batch_size=2, cache_capacity=64, seed=7)
    mixed = {r.uid: r.tokens for r in eng.generate([
        Request(uid=0, prompt=p0, max_new_tokens=5, greedy=True),
        Request(uid=1, prompt=p1, max_new_tokens=5, greedy=False)])}
    ref = DecodeEngine(cfg, params=eng.params, batch_size=2,
                       cache_capacity=64, seed=123)
    pure = {r.uid: r.tokens for r in ref.generate([
        Request(uid=0, prompt=p0, max_new_tokens=5, greedy=True),
        Request(uid=1, prompt=p1, max_new_tokens=5, greedy=True)])}
    assert mixed[0] == pure[0]


# ---------------------------------------------------------------------------
# spgemv-routed compact estimate
# ---------------------------------------------------------------------------

def test_spgemv_estimate_matches_jnp(rng):
    b, hq, hkv, n, d = 2, 8, 2, 256, 64
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    K = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    qk = quantize_int4(K)
    idx = jnp.broadcast_to(jnp.arange(128, dtype=jnp.int32), (b, hkv, 128))
    ref = TwilightPruner(use_spgemv=False).estimate_scores_at(q, idx, qkeys=qk)
    ker = TwilightPruner(use_spgemv=True).estimate_scores_at(q, idx, qkeys=qk)
    # The kernel dequantizes in f32 inside the epilogue; the jnp reference
    # materializes a bf16 K̃ — tolerance covers that rounding gap only.
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)


def test_estimate_backend_resolution():
    import jax
    assert TwilightConfig(estimate_backend="pallas").make_pruner().use_spgemv
    assert not TwilightConfig(estimate_backend="jnp").make_pruner().use_spgemv
    auto = TwilightConfig(estimate_backend="auto").make_pruner().use_spgemv
    assert auto == (jax.default_backend() == "tpu")
    # estimate_bits > 4 has no packed codes to feed the kernel.
    assert not TwilightConfig(estimate_backend="pallas",
                              estimate_bits=16).make_pruner().use_spgemv
