"""Serving engine: wave-batched (contiguous) and continuous (paged) decode.

Three scheduling modes around the same model:

* ``paged=False`` — the legacy wave scheduler: fixed batch slots, every
  request in a wave decodes for the wave's ``max(max_new_tokens)`` against a
  per-slot contiguous cache of ``cache_capacity`` tokens.  Kept as the
  equivalence oracle (same role as ``TwilightConfig.compact=False``).
  Waves are formed so that each request keeps ``cache_capacity -
  max_new_tokens`` of its *own* prompt — a long-prompt/short-generation
  request is no longer truncated by a wave mate's generation budget.
* ``paged=True`` — **true continuous batching** over a shared page pool
  (``repro.serving.paged_cache``): slots retire and admit new requests at
  every decode step; each request owns only the KV pages its tokens fill
  (prefill allocates ceil(len/page_size), decode allocates one page per
  boundary crossing, retirement drops references).  Per-request
  ``max_new_tokens``, ragged prompt lengths, and per-slot sampling modes
  are all data; the jitted step is compiled once per
  (batch, num_pages, max_pages) and reused.
* ``paged=True, prefix_share=True`` — continuous batching plus **prefix
  sharing with copy-on-write pages and chunked prefill** (attention-only
  stacks, :func:`repro.models.supports_chunked_prefill`).  On admission the
  engine matches the longest page-aligned cached prefix in a radix tree
  (``repro.serving.prefix_cache``), takes shared references on those pages,
  and prefills only the suffix — in fixed-size chunks *interleaved with
  decode steps*, so a long admission never stalls live decodes for more
  than one chunk.  Chunk lengths are bucketed (powers of two in pages), so
  the prefill jit cache holds a handful of signatures instead of one per
  exact prompt length.  A fully-cached prompt re-runs only its last token
  for logits; that write lands in a shared page and triggers copy-on-write
  (``PageAllocator.cow`` + the device-side ``models.copy_page``).
  Completed prompts are indexed back into the tree; pool pressure first
  evicts cold refcount-1 tree pages (LRU) and only then preempts.

The decode loop stays async in all modes: sampling runs inside the jitted
step, per-step token/budget frames stay on device, and the host fetches
them ONCE after the queue drains.  Host-side work per step is pure
bookkeeping (page allocation, admission, retirement) on numpy mirrors of
the page table — never a device sync (the one exception: the prefix-share
admission samples the first token from the prefill-chunk logits, exactly
as the unshared path samples from its prefill logits).

When the pool runs dry mid-decode the engine preempts the most recently
admitted victim by *restart*: its page references are dropped and the
request is requeued at the front, to be re-served from its prompt (with
prefix sharing the restart typically re-matches its own pages, making
preemption cheap).  Reference counting makes preemption safe by
construction: dropping the victim's references never reclaims a page the
prefix cache or another live reader still holds.  For greedy requests the
regenerated tokens are identical (asserted in ``tests/test_paged_cache.py``);
sampled requests draw a fresh continuation.  Admission keeps one
boundary-page of headroom per live slot to make preemption rare.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (
    copy_page,
    decode_step,
    decode_step_paged,
    init_paged_decode_state,
    init_params,
    prefill,
    prefill_chunk,
    supports_chunked_prefill,
    write_prefill_slot,
)
from repro.models.common import ModelConfig
from repro.serving.paged_cache import PageAllocator, pad_to_pages, pages_for
from repro.serving.prefix_cache import PrefixCache
from repro.serving.sampler import sample_token

Tree = Any


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (s,) int32
    max_new_tokens: int = 32
    greedy: bool = True
    extras: dict | None = None  # modality-frontend embeddings


@dataclasses.dataclass
class GenerationResult:
    uid: int
    tokens: list[int]
    prompt_len: int
    decode_steps: int
    mean_pruned_budget: float
    wall_s: float


@dataclasses.dataclass
class _SlotRun:
    """Host bookkeeping for one admitted request."""

    req: Request
    slot: int
    pages: list[int]
    t_admit: float
    order: int  # admission sequence number (preemption picks the newest)
    tok0: jax.Array | None = None  # () device scalar — sampled at prefill end
    start_frame: int = 0  # first decode frame this slot participates in
    emitted: int = 0  # tokens sampled so far (tok0 included)
    # Chunked-prefill progress (prefix-share mode only).
    prompt: np.ndarray | None = None  # truncated prompt (tree key)
    matched: int = 0  # tokens reused from the prefix cache
    sfx_done: int = 0  # suffix tokens written so far
    ready: bool = True  # prefill complete — slot decodes

    @property
    def suffix_len(self) -> int:
        return 0 if self.prompt is None else len(self.prompt) - self.matched


class DecodeEngine:
    """Batched decode engine around (prefill, decode_step[_paged])."""

    def __init__(self, cfg: ModelConfig, params: Tree | None = None, *,
                 batch_size: int = 8, cache_capacity: int = 512, seed: int = 0,
                 paged: bool = False, num_pages: int | None = None,
                 prefix_share: bool = False,
                 prefill_chunk_pages: int = 4):
        tw = cfg.twilight
        if tw.enabled and tw.compact and tw.pruned_cap_frac is None:
            # Serving default: B1-scaled final gather (ROADMAP follow-up).
            # The attended buffer is re-compacted to 1/4 of the candidate
            # buffer, far above the paper's measured ~2 %-of-n budgets.
            cfg = cfg.replace(
                twilight=dataclasses.replace(tw, pruned_cap_frac=0.25))
        self.cfg = cfg
        self.batch_size = batch_size
        self.cache_capacity = cache_capacity
        self.paged = paged
        self.prefix_share = prefix_share
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else init_params(cfg, key)
        self._sample_key = jax.random.PRNGKey(seed + 1)

        self._prefill = jax.jit(
            lambda p, batch: prefill(p, cfg, batch, cache_capacity))
        self._decode = jax.jit(lambda p, st, tok: decode_step(p, cfg, st, tok))

        if prefix_share and not paged:
            raise ValueError("prefix_share requires paged=True")
        if paged:
            tw = cfg.twilight
            if not (tw.enabled and tw.compact):
                raise ValueError("paged serving requires the compact "
                                 "Twilight pipeline")
            ps = tw.page_size
            if cache_capacity % ps:
                raise ValueError(f"cache_capacity {cache_capacity} not "
                                 f"divisible by page_size {ps}")
            self.max_pages = cache_capacity // ps
            # Default pool: worst case (every slot full) + the null page —
            # no smaller than wave mode, but callers shrink it to realize
            # the memory win (utilization tracks live tokens, not slots).
            self.num_pages = (num_pages if num_pages is not None
                              else 1 + batch_size * self.max_pages)
            prefix = (cfg.n_prefix_tokens if cfg.frontend == "vision" else 0)
            self._prefill_paged = jax.jit(lambda p, batch: prefill(
                p, cfg, batch,
                pad_to_pages(batch["tokens"].shape[1] + prefix, ps)))
            self._write = jax.jit(
                lambda st, pst, slot, pages: write_prefill_slot(
                    cfg, st, pst, slot, pages),
                donate_argnums=(0,))

            def _step_fn(p, state, tok, pt, lengths, live, greedy, key):
                logits, state, stats = decode_step_paged(
                    p, cfg, state, tok, pt, lengths, live)
                nxt = sample_token(key, logits[:, :cfg.vocab_size],
                                   greedy=greedy)
                return nxt, state, stats["pruned_budget"]

            self._step = jax.jit(_step_fn, donate_argnums=(1,))

            if prefix_share:
                if not supports_chunked_prefill(cfg):
                    raise ValueError(
                        f"{cfg.name}: prefix sharing requires an "
                        "attention-only stack — recurrent mixer state is "
                        "prefix-dependent and must be recomputed "
                        "(supports_chunked_prefill)")
                self.chunk_tokens = max(1, prefill_chunk_pages) * ps
                self._chunk = jax.jit(
                    lambda p, st, toks, pt, slot, start, nv, last:
                    prefill_chunk(p, cfg, st, toks, pt, slot, start, nv,
                                  last),
                    donate_argnums=(1,))
                self._copy_page = jax.jit(
                    lambda st, src, dst: copy_page(cfg, st, src, dst),
                    donate_argnums=(0,))

    # -- dispatch -----------------------------------------------------------

    def generate(self, requests: list[Request]) -> list[GenerationResult]:
        """Serve requests: continuous batching when paged, else waves."""
        if self.paged:
            return self._serve_continuous(requests)
        results: list[GenerationResult] = []
        queue = list(requests)
        while queue:
            wave, queue = self._form_wave(queue)
            results.extend(self._serve_wave(wave))
        return results

    # -- wave mode (the contiguous-cache oracle) ----------------------------

    def _own_keep(self, req: Request) -> int:
        """Prompt tokens request may keep under its *own* decode budget."""
        return max(1, self.cache_capacity - req.max_new_tokens)

    def _form_wave(self, queue: list[Request]
                   ) -> tuple[list[Request], list[Request]]:
        """FIFO wave packing under the shared-position constraint.

        Every slot in a wave appends at the same cache position, so the
        wave must satisfy ``max(kept prompt) + max(max_new) <= capacity``.
        Clipping each prompt to its own ``capacity - max_new`` budget and
        closing the wave when a newcomer would violate the bound means a
        long-prompt/short-generation request is never truncated by a wave
        mate's generation budget (it previously was — the wave-wide
        ``max(max_new_tokens)`` clipped every prompt).
        """
        wave: list[Request] = []
        s = wave_max = 0
        while queue and len(wave) < self.batch_size:
            r = queue[0]
            if r.max_new_tokens >= self.cache_capacity:
                raise ValueError(
                    f"request {r.uid}: max_new_tokens {r.max_new_tokens} "
                    f"cannot fit cache_capacity {self.cache_capacity}")
            ns = max(s, min(len(r.prompt), self._own_keep(r)))
            nmax = max(wave_max, r.max_new_tokens)
            if wave and ns + nmax > self.cache_capacity:
                break
            wave.append(queue.pop(0))
            s, wave_max = ns, nmax
        return wave, queue

    def _serve_wave(self, wave: list[Request]) -> list[GenerationResult]:
        t0 = time.time()
        b = len(wave)
        # Each prompt is clipped by its OWN max_new_tokens; _form_wave
        # guarantees the resulting batch fits the shared cache.
        clipped = [r.prompt[-self._own_keep(r):] for r in wave]
        s = max(len(p) for p in clipped)
        max_new = max(r.max_new_tokens for r in wave)
        assert s + max_new <= self.cache_capacity, "wave packing invariant"
        toks = np.zeros((b, s), np.int32)
        for i, pr in enumerate(clipped):
            toks[i, -len(pr):] = pr  # left-pad with token 0
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.frontend == "audio":
            frames = np.stack([r.extras["frames"] for r in wave])
            batch["frames"] = jnp.asarray(frames)
        elif self.cfg.frontend == "vision":
            patches = np.stack([r.extras["patches"] for r in wave])
            batch["patches"] = jnp.asarray(patches)

        logits, state = self._prefill(self.params, batch)
        last = logits[:, -1, :self.cfg.vocab_size]  # drop padded vocab rows
        # Per-slot sampling mode: a greedy and a sampling request can share
        # a wave (previously collapsed to all(r.greedy)).  A uniform wave
        # keeps the Python-bool fast path (argmax only — no wasted
        # softmax/top-p work for the common all-greedy case).
        modes = [r.greedy for r in wave]
        greedy = modes[0] if len(set(modes)) == 1 else jnp.asarray(modes)
        # The decode loop stays async: tokens and the budget accumulator
        # live on device and are fetched ONCE per wave.  A float()/asarray()
        # inside the loop would block on the device every token and
        # serialize dispatch against compute.
        out_toks_dev = []
        budget_sum = jnp.zeros((), jnp.float32)
        for step in range(max_new):
            self._sample_key, k = jax.random.split(self._sample_key)
            tok = sample_token(k, last, greedy=greedy)
            out_toks_dev.append(tok)
            last, state, stats = self._decode(self.params, state, tok)
            last = last[:, :self.cfg.vocab_size]
            budget_sum = budget_sum + stats["mean_pruned_budget"]

        out_tokens = (np.stack([np.asarray(t) for t in out_toks_dev], axis=1)
                      if out_toks_dev else np.zeros((b, 0), np.int32))
        mean_budget = float(budget_sum) / max_new if max_new else 0.0
        wall = time.time() - t0
        results = []
        for i, r in enumerate(wave):
            results.append(GenerationResult(
                uid=r.uid,
                tokens=out_tokens[i, :r.max_new_tokens].tolist(),
                prompt_len=len(r.prompt),
                decode_steps=r.max_new_tokens,
                mean_pruned_budget=mean_budget,
                wall_s=wall,
            ))
        return results

    # -- continuous mode (paged pool) ---------------------------------------

    def _batch_one(self, req: Request, prompt: np.ndarray) -> dict:
        batch = {"tokens": jnp.asarray(prompt[None])}
        if self.cfg.frontend == "audio":
            batch["frames"] = jnp.asarray(req.extras["frames"][None])
        elif self.cfg.frontend == "vision":
            batch["patches"] = jnp.asarray(req.extras["patches"][None])
        return batch

    def _sample_one(self, logits_row: jax.Array, greedy: bool) -> jax.Array:
        self._sample_key, k = jax.random.split(self._sample_key)
        return sample_token(k, logits_row[None], greedy=greedy)[0]

    def _chunk_bucket(self, n: int) -> int:
        """Smallest power-of-two multiple of page_size >= n tokens, capped
        at the configured chunk length — the handful of jit signatures the
        chunked-prefill path compiles."""
        ps = self.cfg.twilight.page_size
        c = ps
        while c < min(n, self.chunk_tokens):
            c *= 2
        return min(c, self.chunk_tokens)

    def _truncate(self, req: Request, prefix: int) -> np.ndarray:
        """Clip the prompt so prompt + generation fits the cache capacity."""
        prompt = np.asarray(req.prompt, np.int32)
        cap = self.cache_capacity - prefix
        if req.max_new_tokens >= cap:
            raise ValueError(
                f"request {req.uid}: max_new_tokens "
                f"{req.max_new_tokens} cannot fit cache_capacity "
                f"{self.cache_capacity} (prefix {prefix})")
        keep = cap - req.max_new_tokens  # >= 1
        return prompt[-keep:] if len(prompt) > keep else prompt

    def _serve_continuous(self, requests: list[Request]
                          ) -> list[GenerationResult]:
        # Telemetry, inspected by tests/benchmarks.
        self.last_preemptions = 0
        self.last_prefix_hits = 0  # admissions that reused cached pages
        self.last_prefix_tokens = 0  # prompt tokens served from the cache
        self.last_cow_copies = 0  # shared pages copied before a write
        self.last_evictions = 0  # tree pages reclaimed under pressure
        self.last_prefill_chunks = 0
        if not requests:
            return []
        cfg = self.cfg
        ps = cfg.twilight.page_size
        prefix = cfg.n_prefix_tokens if cfg.frontend == "vision" else 0
        b = self.batch_size
        n_enc = 0
        if cfg.frontend == "audio":
            n_enc = len(requests[0].extras["frames"])
            if any(len(r.extras["frames"]) != n_enc for r in requests):
                raise ValueError("audio requests must share a frame length")

        alloc = PageAllocator(self.num_pages)
        tree = PrefixCache(ps, alloc) if self.prefix_share else None
        state = init_paged_decode_state(cfg, b, self.num_pages, n_enc=n_enc)
        pt = np.zeros((b, self.max_pages), np.int32)
        lengths = np.zeros((b,), np.int32)
        live = np.zeros((b,), bool)
        greedy = np.ones((b,), bool)
        slots: list[_SlotRun | None] = [None] * b
        pending: deque[Request] = deque(requests)
        cur_tok = jnp.zeros((b,), jnp.int32)
        tok_frames: list[jax.Array] = []  # (b,) per step, stay on device
        budget_frames: list[jax.Array] = []
        done: list[tuple[_SlotRun, float]] = []  # (run, retire time)
        order = 0

        def reclaim(want: int) -> None:
            """Pool pressure: evict cold prefix-cache pages before anything
            drastic.  No-op when sharing is off or the tree has no
            refcount-1 pages."""
            if tree is not None and want > 0:
                self.last_evictions += tree.evict(want)

        def go_live(run: _SlotRun, s_total: int) -> None:
            nonlocal cur_tok
            slot = run.slot
            run.ready = True
            run.emitted = 1
            run.start_frame = len(tok_frames)
            if tree is not None and run.prompt is not None:
                tree.insert(run.prompt, run.pages[:len(run.prompt) // ps])
            if run.req.max_new_tokens <= 1:
                alloc.free(run.pages)
                slots[slot] = None
                pt[slot] = 0
                done.append((run, time.time()))
                return
            lengths[slot] = s_total
            live[slot] = True
            greedy[slot] = run.req.greedy
            cur_tok = cur_tok.at[slot].set(run.tok0)

        def admit(slot: int) -> bool:
            """Unshared admission: one-shot contiguous prefill scattered
            into freshly-allocated pages (the token-exactness oracle for
            the prefix-share path)."""
            nonlocal state, order
            req = pending[0]
            prompt = self._truncate(req, prefix)
            s_total = len(prompt) + prefix
            worst = pages_for(s_total + req.max_new_tokens, ps)
            if worst > alloc.capacity:
                raise ValueError(
                    f"request {req.uid} needs {worst} pages; pool has "
                    f"{alloc.capacity} — raise num_pages")
            n_req = pages_for(s_total, ps)
            live_count = sum(1 for r in slots if r is not None)
            # Alone, a request is admitted only if its worst case fits (it
            # then completes without preemption — no livelock); alongside
            # live slots, keep one boundary page of headroom per slot.
            need = worst if live_count == 0 else n_req + live_count
            if alloc.available < need:
                return False
            pending.popleft()
            pages = alloc.alloc(n_req)
            logits, pstate = self._prefill_paged(
                self.params, self._batch_one(req, prompt))
            state = self._write(state, pstate, jnp.int32(slot),
                                jnp.asarray(pages, jnp.int32))
            tok0 = self._sample_one(logits[0, s_total - 1, :cfg.vocab_size],
                                    req.greedy)
            run = _SlotRun(req=req, slot=slot, pages=pages, tok0=tok0,
                           t_admit=time.time(), order=order)
            order += 1
            slots[slot] = run
            pt[slot, :n_req] = pages
            pt[slot, n_req:] = 0
            go_live(run, s_total)
            return True

        def admit_shared(slot: int, use_cache: bool = True) -> bool:
            """Prefix-share admission: match the longest page-aligned
            cached prefix, take shared references, and stage the suffix for
            chunked prefill.  A fully-cached prompt keeps its last token as
            the suffix (its logits seed sampling); that token's write hits
            a shared page, which is exactly the copy-on-write append."""
            nonlocal state, order
            req = pending[0]
            prompt = self._truncate(req, prefix)
            s_total = len(prompt)
            worst = pages_for(s_total + req.max_new_tokens, ps)
            if worst > alloc.capacity:
                raise ValueError(
                    f"request {req.uid} needs {worst} pages; pool has "
                    f"{alloc.capacity} — raise num_pages")
            pages_m, matched = (tree.match(prompt) if use_cache
                                else ([], 0))
            cow = False
            if matched == s_total:
                matched -= 1  # re-run the last token for its logits
                cow = True
            n_new = pages_for(s_total, ps) - len(pages_m) + (1 if cow else 0)
            live_count = sum(1 for r in slots if r is not None)
            need = (worst - len(pages_m) + (1 if cow else 0)
                    if live_count == 0 else n_new + live_count)
            if alloc.available < need:
                reclaim(need - alloc.available)
            if alloc.available < need:
                if pages_m:
                    alloc.free(pages_m)
                if live_count == 0 and use_cache:
                    # Alone and still short: the match itself may pin the
                    # pool (e.g. worst == capacity and the COW page cannot
                    # fit).  Retry cold — eviction can then reclaim
                    # everything, and worst <= capacity guarantees admission.
                    return admit_shared(slot, use_cache=False)
                return False
            pending.popleft()
            if matched:
                self.last_prefix_hits += 1
                self.last_prefix_tokens += matched
            if cow:
                src = pages_m[-1]
                new, copied = alloc.cow(src)
                if copied:
                    state = self._copy_page(state, jnp.int32(src),
                                            jnp.int32(new))
                    self.last_cow_copies += 1
                pages_m = pages_m[:-1] + [new]
            run = _SlotRun(req=req, slot=slot, pages=list(pages_m),
                           t_admit=time.time(), order=order, prompt=prompt,
                           matched=matched, ready=False)
            order += 1
            slots[slot] = run
            pt[slot, :len(run.pages)] = run.pages
            pt[slot, len(run.pages):] = 0
            lengths[slot] = 0
            live[slot] = False
            return True

        def retire(slot: int, preempted: bool = False) -> None:
            run = slots[slot]
            alloc.free(run.pages)
            slots[slot] = None
            live[slot] = False
            pt[slot] = 0
            lengths[slot] = 0
            # Reset the sampling mode so a freed slot doesn't carry its
            # previous occupant's mode into the jitted step before
            # re-admission (greedy is the junk-safe default: no stray
            # top-p draw for a dead slot).
            greedy[slot] = True
            if preempted:
                pending.appendleft(run.req)
            else:
                done.append((run, time.time()))

        def preempt_for_page(needy: int) -> None:
            victims = [r for r in (slots[s] for s in range(b))
                       if r is not None and r.slot != needy]
            victim = (max(victims, key=lambda r: r.order).slot
                      if victims else needy)
            self.last_preemptions += 1
            retire(victim, preempted=True)

        def ensure_pages(need: int, needy: int) -> bool:
            """Make ``need`` pages available for slot ``needy``: evict cold
            tree pages first, then preempt newest-first — re-trying
            eviction after every preemption, since retiring a victim whose
            pages are tree-shared frees nothing directly but exposes those
            pages for reclaim.  Returns False if ``needy`` itself was
            preempted (last resort)."""
            if alloc.available < need:
                reclaim(need - alloc.available)
            while alloc.available < need:
                preempt_for_page(needy)
                if alloc.available < need:
                    reclaim(need - alloc.available)
                if slots[needy] is None:
                    return False
            return True

        def advance_prefill(run: _SlotRun) -> None:
            """Write one (bucketed) chunk of ``run``'s suffix into pool
            pages; completing the suffix samples tok0 and flips the slot
            live."""
            nonlocal state
            slot = run.slot
            start = run.matched + run.sfx_done
            remaining = run.suffix_len - run.sfx_done
            n_valid = min(remaining, self.chunk_tokens)
            c = self._chunk_bucket(n_valid)  # >= n_valid by construction
            need = pages_for(start + n_valid, ps) - len(run.pages)
            if need > 0:
                if not ensure_pages(need, slot) or slots[slot] is not run:
                    return  # self-preempted
                new_pages = alloc.alloc(need)
                pt[slot, len(run.pages):len(run.pages) + need] = new_pages
                run.pages.extend(new_pages)
            toks = np.zeros((c,), np.int32)
            toks[:n_valid] = run.prompt[start:start + n_valid]
            is_last = run.sfx_done + n_valid >= run.suffix_len
            logits, state = self._chunk(
                self.params, state, jnp.asarray(toks),
                jnp.asarray(pt[slot]), jnp.int32(slot), jnp.int32(start),
                jnp.int32(n_valid), jnp.asarray(is_last))
            self.last_prefill_chunks += 1
            run.sfx_done += n_valid
            if run.sfx_done >= run.suffix_len:
                run.tok0 = self._sample_one(
                    logits[0, n_valid - 1, :cfg.vocab_size], run.req.greedy)
                go_live(run, len(run.prompt))

        while pending or any(r is not None for r in slots):
            # Admission: fill every free slot while the queue and pool allow
            # (an instantly-retired max_new=1 request frees its slot again).
            slot = 0
            while pending and slot < b:
                if slots[slot] is None:
                    ok = (admit_shared(slot) if self.prefix_share
                          else admit(slot))
                    if not ok:
                        break
                    if slots[slot] is None:
                        continue
                slot += 1
            # Advance ONE prefilling slot by one chunk, oldest first —
            # interleaving admission work with decode steps bounds the
            # decode stall a long admission can cause to one chunk.
            prefilling = [r for r in slots if r is not None and not r.ready]
            if prefilling:
                advance_prefill(min(prefilling, key=lambda r: r.order))
            if not any(live):
                if pending or any(r is not None for r in slots):
                    # Nothing decodable yet: either prefills are still in
                    # flight or admission stalls transiently after mass
                    # preemption; loop.
                    continue
                break
            # Boundary pages for this step's appends.
            for slot in range(b):
                if live[slot] and lengths[slot] % ps == 0:
                    if not ensure_pages(1, slot) or not live[slot]:
                        continue  # self-preempted (last resort)
                    page = alloc.alloc(1)[0]
                    slots[slot].pages.append(page)
                    pt[slot, lengths[slot] // ps] = page
            if not any(live):
                continue
            # One jitted step for the whole batch; dead slots compute junk
            # into the null page.
            self._sample_key, k = jax.random.split(self._sample_key)
            cur_tok, state, budget = self._step(
                self.params, state, cur_tok, jnp.asarray(pt),
                jnp.asarray(lengths), jnp.asarray(live), jnp.asarray(greedy),
                k)
            tok_frames.append(cur_tok)
            budget_frames.append(budget)
            for slot in range(b):
                if not live[slot]:
                    continue
                lengths[slot] += 1
                run = slots[slot]
                run.emitted += 1
                if run.emitted >= run.req.max_new_tokens:
                    retire(slot)

        # Single host sync: fetch every decode frame at once.
        toks = (np.stack([np.asarray(t) for t in tok_frames])
                if tok_frames else np.zeros((0, b), np.int32))
        buds = (np.stack([np.asarray(x) for x in budget_frames])
                if budget_frames else np.zeros((0, b), np.float32))
        results = []
        for run, t_done in done:
            n_dec = run.req.max_new_tokens - 1
            frames = toks[run.start_frame:run.start_frame + n_dec, run.slot]
            frame_buds = buds[run.start_frame:run.start_frame + n_dec,
                              run.slot]
            results.append(GenerationResult(
                uid=run.req.uid,
                tokens=[int(np.asarray(run.tok0))] + frames.tolist(),
                prompt_len=len(run.req.prompt),
                decode_steps=run.req.max_new_tokens,
                mean_pruned_budget=(float(frame_buds.mean())
                                    if len(frame_buds) else 0.0),
                wall_s=t_done - run.t_admit,
            ))
        return results
