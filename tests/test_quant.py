"""INT4 asymmetric quantization: round-trip bounds and packing layout."""

import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional dep: the property test degrades to a fixed sweep without it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    HAVE_HYPOTHESIS = False

from repro.core.quant import dequantize_int4, quantize_int4


@pytest.mark.parametrize("shape", [(4, 64), (2, 16, 4, 128), (1, 7, 3, 32)])
def test_roundtrip_error_bound(rng, shape):
    x = jnp.asarray(rng.normal(size=shape), jnp.float32)
    qt = quantize_int4(x)
    xd = dequantize_int4(qt)
    # Error per element <= scale/2 (round-to-nearest on 15 levels).
    bound = np.asarray(qt.scale) / 2 + 1e-6
    assert (np.abs(np.asarray(xd - x)) <= bound).all()


def test_packing_layout(rng):
    x = jnp.asarray(rng.normal(size=(3, 8)), jnp.float32)
    qt = quantize_int4(x)
    assert qt.packed.shape == (3, 4)
    assert qt.packed.dtype == jnp.uint8
    # Low nibble = even channel, high nibble = odd channel.
    xd = np.asarray(dequantize_int4(qt))
    scale = np.asarray(qt.scale)
    zero = np.asarray(qt.zero)
    codes = np.round((np.asarray(x) - zero) / scale).clip(0, 15).astype(np.uint8)
    packed = np.asarray(qt.packed)
    np.testing.assert_array_equal(packed & 0xF, codes[:, 0::2])
    np.testing.assert_array_equal(packed >> 4, codes[:, 1::2])
    del xd


def test_odd_last_dim_rejected():
    with pytest.raises(ValueError):
        quantize_int4(jnp.ones((2, 7)))


def _roundtrip_property(d, scale_mag, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(8, d)) * scale_mag, jnp.float32)
    qt = quantize_int4(x)
    xd = dequantize_int4(qt)
    bound = np.asarray(qt.scale) / 2 + 1e-5 * scale_mag
    assert (np.abs(np.asarray(xd - x)) <= bound).all()


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(
        d=st.sampled_from([16, 32, 64, 128]),
        scale_mag=st.floats(1e-3, 1e3),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property_roundtrip(d, scale_mag, seed):
        _roundtrip_property(d, scale_mag, seed)
else:
    @pytest.mark.parametrize("d", [16, 32, 64, 128])
    @pytest.mark.parametrize("scale_mag", [1e-3, 1.0, 1e3])
    @pytest.mark.parametrize("seed", [0, 1234567])
    def test_property_roundtrip(d, scale_mag, seed):
        _roundtrip_property(d, scale_mag, seed)


def test_constant_rows_stable(rng):
    x = jnp.ones((4, 32)) * 3.7
    xd = dequantize_int4(quantize_int4(x))
    np.testing.assert_allclose(np.asarray(xd), 3.7, atol=1e-5)


def test_score_estimation_quality(rng):
    """INT4 scores must preserve enough ordering for top-p (paper Fig. 6)."""
    q = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    K = jnp.asarray(rng.normal(size=(512, 64)), jnp.float32)
    exact = np.asarray(K @ q)
    est = np.asarray(dequantize_int4(quantize_int4(K)) @ q)
    corr = np.corrcoef(exact, est)[0, 1]
    assert corr > 0.99, f"INT4 score correlation too low: {corr}"
