"""Public wrapper: estimated attention scores from the INT4 shadow cache.

Adapts the model/cache layout — q (b, hq, d), QuantizedTensor over
(b, n, hkv, d) — to the kernel's (B=b*hkv, group, ...) layout, including the
query de-interleave that matches the nibble packing.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import QuantizedTensor
from repro.kernels.spgemv.kernel import spgemv_scores


def estimate_scores(
    q: jax.Array,  # (b, hq, d)
    qkeys: QuantizedTensor,  # packed (b, n, hkv, d//2)
    *,
    sm_scale: float | None = None,
    block_n: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Returns (b, hq, n) f32 estimated scores (pre-softmax)."""
    b, hq, d = q.shape
    _, n, hkv, d2 = qkeys.packed.shape
    group = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)

    qg = q.reshape(b, hkv, group, d).reshape(b * hkv, group, d)
    q_even = qg[..., 0::2]
    q_odd = qg[..., 1::2]
    packed = jnp.moveaxis(qkeys.packed, 2, 1).reshape(b * hkv, n, d2)
    scale = jnp.moveaxis(qkeys.scale[..., 0], 2, 1).reshape(b * hkv, n)
    zero = jnp.moveaxis(qkeys.zero[..., 0], 2, 1).reshape(b * hkv, n)

    scores = spgemv_scores(
        q_even, q_odd, packed, scale, zero,
        sm_scale=float(sm_scale), block_n=block_n, interpret=interpret,
    )  # (b*hkv, group, n)
    return scores.reshape(b, hkv, group, n).reshape(b, hq, n)


def estimate_scores_gathered(
    q: jax.Array,  # (b, hq, d)
    qkeys: QuantizedTensor,  # gathered candidate rows: packed (b, hkv, m, d//2)
    valid: jax.Array | None = None,  # (b, hkv, m) bool — live candidate slots
    *,
    sm_scale: float | None = None,
    block_n: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Compact-pipeline estimate: scores over a pre-gathered candidate buffer.

    The hot serving path — only the m candidate rows' packed codes (d/2+8
    bytes each) are touched, and the dequantization runs in the kernel
    epilogue.  Returns (b, hkv, group, m) f32, matching the layout of
    ``TwilightPruner.estimate_scores_at``.

    ``valid`` turns on the kernel's dead-block early-out (the hierarchical
    page nucleus leaves whole pages of slots invalid): blocks with no live
    slot skip their matmuls and return zeros.  Dead-slot scores are
    unspecified either way — consumers mask on ``valid`` before softmax.
    """
    b, hkv, m, d2 = qkeys.packed.shape
    hq, d = q.shape[1], q.shape[2]
    group = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)

    qg = q.reshape(b, hkv, group, d).reshape(b * hkv, group, d)
    scores = spgemv_scores(
        qg[..., 0::2], qg[..., 1::2],
        qkeys.packed.reshape(b * hkv, m, d2),
        qkeys.scale[..., 0].reshape(b * hkv, m),
        qkeys.zero[..., 0].reshape(b * hkv, m),
        None if valid is None else valid.reshape(b * hkv, m),
        sm_scale=float(sm_scale), block_n=block_n, interpret=interpret,
    )  # (b*hkv, group, m)
    return scores.reshape(b, hkv, group, m)
