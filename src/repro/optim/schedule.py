"""LR schedules as pure functions of the step (jit-safe)."""

from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, warmup_steps: int, peak: float):
    s = jnp.asarray(step, jnp.float32)
    return peak * jnp.minimum(1.0, (s + 1.0) / max(1, warmup_steps))


def cosine_schedule(step, warmup_steps: int, total_steps: int, peak: float,
                    floor: float = 0.1):
    s = jnp.asarray(step, jnp.float32)
    warm = linear_warmup(step, warmup_steps, peak)
    frac = jnp.clip((s - warmup_steps) / max(1, total_steps - warmup_steps),
                    0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(s < warmup_steps, warm, peak * cos)
