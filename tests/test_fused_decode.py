"""Fused single-launch decode kernel vs the staged compact pipeline.

The fused kernel (``kernels/fused_decode``) runs estimate → top-p → sparse
attention as ONE Pallas launch.  The staged compact pipeline is the
equivalence oracle; for apples-to-apples numerics the staged estimate is
pinned to the spgemv backend (``estimate_backend="pallas"``) so both sides
compute scores in f32 code space, and ``pruned_cap_frac=1.0`` so the
staged path attends the full kept set exactly as the fused kernel does.

Levels, mirroring how the feature is layered:

* op — ``fused_prune_attend`` vs the pure-jnp ``fused_prune_attend_ref``;
* core — ``twilight_decode_attention`` fused vs staged for every selector,
  contiguous and paged (shuffled pool + page tables), ragged lengths;
* engine — paged continuous batching emits token-identical results fused
  vs staged, greedy AND sampled, including H2O (whose page-mass feed is
  the fused kernel's ``slot_weights`` output — asserted bit-equal on the
  pool accumulator).

Plus the top-p edge cases for both kernels: p→0 (budget collapses to the
argmax slot per query head), p=1.0 (keeps every valid candidate),
fully-masked rows, and a candidate budget smaller than one page.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import (
    SelectionContext,
    TwilightConfig,
    build_page_meta,
    calibrate_ds_channels,
    quantize_int4,
    twilight_decode_attention,
)
from repro.kernels.fused_decode.ops import fused_prune_attend
from repro.kernels.fused_decode.ref import fused_prune_attend_ref
from repro.serving import DecodeEngine, Request
from tests.test_paged_cache import _paged_fixture

SELECTORS = ("full", "quest", "double_sparsity", "streaming", "h2o")


def _cfg(selector="quest", fused="staged", **kw):
    """Staged/fused config pair base: identical numerics on both paths."""
    kw.setdefault("p", 0.9)
    kw.setdefault("candidate_frac", 0.5)
    kw.setdefault("page_size", 16)
    kw.setdefault("min_candidate", 64)
    return TwilightConfig(selector=selector, estimate_backend="pallas",
                          pruned_cap_frac=1.0, fused_backend=fused, **kw)


def _setup(rng, b=2, hq=8, hkv=2, n=512, d=64):
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    K = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    return q, K, V


def _ctx(rng, K, length=None, page=16):
    b, n, hkv, _ = K.shape
    return SelectionContext(
        keys=K,
        page_meta=build_page_meta(K, page),
        accum_scores=jnp.asarray(rng.random((b, hkv, n)), jnp.float32),
        length=length,
        ds_channels=calibrate_ds_channels(K, 8),
    )


def _assert_fused_matches_staged(fused, staged, *, out_tol=1e-4):
    np.testing.assert_array_equal(np.asarray(fused.pruned_valid),
                                  np.asarray(staged.pruned_valid))
    np.testing.assert_array_equal(np.asarray(fused.candidate_valid),
                                  np.asarray(staged.candidate_valid))
    np.testing.assert_array_equal(np.asarray(fused.stats.candidate_budget),
                                  np.asarray(staged.stats.candidate_budget))
    np.testing.assert_array_equal(np.asarray(fused.stats.pruned_budget),
                                  np.asarray(staged.stats.pruned_budget))
    np.testing.assert_allclose(np.asarray(fused.slot_weights),
                               np.asarray(staged.slot_weights),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(fused.stats.threshold),
                               np.asarray(staged.stats.threshold),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(fused.out), np.asarray(staged.out),
                               rtol=out_tol, atol=out_tol)


# ---------------------------------------------------------------------------
# Op level: kernel vs the pure-jnp reference
# ---------------------------------------------------------------------------

def test_fused_op_matches_ref(rng):
    q, K, V = _setup(rng, n=256)
    b, n, hkv, d = K.shape
    m = 128
    qkeys = quantize_int4(K)
    idx = jnp.asarray(np.sort(rng.choice(n, size=(b, hkv, m)), -1), jnp.int32)
    valid = jnp.asarray(rng.random((b, hkv, m)) < 0.9)
    idx = jnp.where(valid, idx, 0)
    out, kept, w, th = fused_prune_attend(q, idx, valid, K, V, qkeys, p=0.9)
    ro, rk, rw, rt = fused_prune_attend_ref(q, idx, valid, K, V, qkeys, p=0.9)
    np.testing.assert_array_equal(np.asarray(kept), np.asarray(rk))
    np.testing.assert_allclose(np.asarray(w), np.asarray(rw),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(th), np.asarray(rt),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ro),
                               rtol=1e-4, atol=1e-5)


def test_fused_op_all_masked_row_emits_zeros(rng):
    """A fully-invalid candidate row (dead engine slot) keeps nothing and
    outputs exact zeros — in the kernel AND the staged pruner."""
    q, K, V = _setup(rng, n=256)
    b, n, hkv, d = K.shape
    m, group = 128, q.shape[1] // hkv
    qkeys = quantize_int4(K)
    idx = jnp.asarray(np.sort(rng.choice(n, size=(b, hkv, m)), -1), jnp.int32)
    valid = jnp.asarray(rng.random((b, hkv, m)) < 0.9).at[0, 0].set(False)
    idx = jnp.where(valid, idx, 0)
    out, kept, w, th = fused_prune_attend(q, idx, valid, K, V, qkeys, p=0.9)
    assert not np.asarray(kept)[0, 0].any()
    assert (np.asarray(w)[0, 0] == 0).all()
    np.testing.assert_array_equal(np.asarray(out)[0, :group], 0.0)
    # Staged: same dead row through prune_at.
    pruner = _cfg().make_pruner()
    kept_s, _, w_s = pruner.prune_at(q, idx, valid, keys=K, qkeys=qkeys)
    assert not np.asarray(kept_s)[0, 0].any()
    assert (np.asarray(w_s)[0, 0] == 0).all()


# ---------------------------------------------------------------------------
# Core: fused pipeline vs staged pipeline, contiguous and paged
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("selector", SELECTORS)
@pytest.mark.parametrize("ragged", [False, True])
def test_fused_pipeline_matches_staged(rng, selector, ragged):
    q, K, V = _setup(rng)
    length = jnp.asarray([512, 300]) if ragged else None
    ctx = _ctx(rng, K, length=length)
    staged = twilight_decode_attention(
        q, K, V, _cfg(selector, "staged"), ctx=ctx, length=length)
    fused = twilight_decode_attention(
        q, K, V, _cfg(selector, "fused"), ctx=ctx, length=length)
    _assert_fused_matches_staged(fused, staged)


@pytest.mark.parametrize("selector", SELECTORS)
def test_fused_pipeline_matches_staged_paged(rng, selector):
    """Shuffled physical pool + page tables: the fused kernel DMAs from the
    pool at pre-translated physical rows, exactly like the staged gathers."""
    fx = _paged_fixture(rng)
    length = jnp.asarray([256, 180])
    kw = dict(candidate_frac=0.5, min_candidate=64)
    staged = twilight_decode_attention(
        fx["q"], fx["k_pool"], fx["v_pool"], _cfg(selector, "staged", **kw),
        ctx=fx["ctx_paged"](length), qkeys=fx["qkeys_pool"], length=length)
    fused = twilight_decode_attention(
        fx["q"], fx["k_pool"], fx["v_pool"], _cfg(selector, "fused", **kw),
        ctx=fx["ctx_paged"](length), qkeys=fx["qkeys_pool"], length=length)
    _assert_fused_matches_staged(fused, staged)


def test_fused_budget_below_one_page(rng):
    """B0 smaller than one page: the page-granular selector still emits one
    whole page and both paths agree (incl. the dense oracle)."""
    q, K, V = _setup(rng, n=256)
    ctx = _ctx(rng, K)
    kw = dict(fixed_budget=8, candidate_frac=0.25, min_candidate=1)
    staged = twilight_decode_attention(q, K, V, _cfg("quest", "staged", **kw),
                                       ctx=ctx)
    fused = twilight_decode_attention(q, K, V, _cfg("quest", "fused", **kw),
                                      ctx=ctx)
    assert int(np.asarray(staged.stats.candidate_budget).max()) <= 16
    _assert_fused_matches_staged(fused, staged)
    dense = twilight_decode_attention(
        q, K, V, dataclasses.replace(_cfg("quest", "staged", **kw),
                                     compact=False), ctx=ctx)
    np.testing.assert_allclose(np.asarray(fused.out), np.asarray(dense.out),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Top-p edge cases, fused and staged
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["staged", "fused"])
def test_p_to_zero_collapses_to_argmax(rng, backend):
    """p→0: the binary search converges to max(w), so each query head keeps
    exactly its argmax slot; the loaded set is the group union of argmaxes."""
    q, K, V = _setup(rng)
    ctx = _ctx(rng, K)
    out = twilight_decode_attention(
        q, K, V, _cfg("quest", backend, p=1e-9), ctx=ctx)
    b, hkv, _ = out.pruned_valid.shape
    group = q.shape[1] // hkv
    budgets = np.asarray(out.stats.pruned_budget)
    assert (budgets >= 1).all() and (budgets <= group).all()


def test_p_to_zero_fused_matches_staged(rng):
    q, K, V = _setup(rng)
    ctx = _ctx(rng, K)
    staged = twilight_decode_attention(q, K, V, _cfg("quest", "staged",
                                                     p=1e-9), ctx=ctx)
    fused = twilight_decode_attention(q, K, V, _cfg("quest", "fused",
                                                    p=1e-9), ctx=ctx)
    _assert_fused_matches_staged(fused, staged)


@pytest.mark.parametrize("backend", ["staged", "fused"])
def test_p_one_keeps_all_valid(rng, backend):
    """p=1.0: no threshold below the full mass exists, so every valid
    candidate survives (thresholds may differ in the last ulp between
    backends — the *set* semantics are what is pinned here)."""
    q, K, V = _setup(rng)
    length = jnp.asarray([512, 300])
    ctx = _ctx(rng, K, length=length)
    out = twilight_decode_attention(
        q, K, V, _cfg("quest", backend, p=1.0), ctx=ctx, length=length)
    np.testing.assert_array_equal(np.asarray(out.pruned_valid),
                                  np.asarray(out.candidate_valid))


# ---------------------------------------------------------------------------
# Engine: fused serving is token-exact vs staged, greedy and sampled
# ---------------------------------------------------------------------------

def _serving_cfg(selector="quest", fused="staged"):
    cfg = get_smoke_config("qwen2-1.5b")
    return cfg.replace(twilight=dataclasses.replace(
        cfg.twilight, selector=selector, estimate_backend="pallas",
        pruned_cap_frac=1.0, fused_backend=fused))


def test_engine_fused_matches_staged_greedy_and_sampled(rng):
    reqs = []
    cfg_s = _serving_cfg("quest", "staged")
    for uid, (L, mn, greedy) in enumerate([(24, 5, True), (17, 4, False),
                                           (9, 3, True), (13, 4, False)]):
        reqs.append(Request(
            uid=uid, prompt=rng.integers(8, cfg_s.vocab_size, L
                                         ).astype(np.int32),
            max_new_tokens=mn, greedy=greedy))
    staged = DecodeEngine(cfg_s, batch_size=2, cache_capacity=64, seed=7,
                          paged=True)
    fused = DecodeEngine(_serving_cfg("quest", "fused"), params=staged.params,
                         batch_size=2, cache_capacity=64, seed=7, paged=True)
    want = {r.uid: r.tokens for r in staged.generate(reqs)}
    got = {r.uid: r.tokens for r in fused.generate(reqs)}
    assert got == want


def test_engine_fused_h2o_token_exact_with_mass_parity(rng):
    """Paged H2O fed by the fused kernel's ``slot_weights``: tokens AND the
    per-physical-page mass accumulator must match the staged engine."""
    cfg_s = _serving_cfg("h2o", "staged")
    reqs = [Request(uid=uid,
                    prompt=rng.integers(8, cfg_s.vocab_size, L
                                        ).astype(np.int32),
                    max_new_tokens=mn)
            for uid, (L, mn) in enumerate([(24, 5), (17, 3), (9, 4)])]
    staged = DecodeEngine(cfg_s, batch_size=2, cache_capacity=64, seed=7,
                          paged=True)
    fused = DecodeEngine(_serving_cfg("h2o", "fused"), params=staged.params,
                         batch_size=2, cache_capacity=64, seed=7, paged=True)
    want = {r.uid: r.tokens for r in staged.generate(reqs)}
    got = {r.uid: r.tokens for r in fused.generate(reqs)}
    assert got == want
    flat_s = jax.tree_util.tree_leaves_with_path(staged._state)
    flat_f = dict(jax.tree_util.tree_leaves_with_path(fused._state))
    mass = [(p, s) for p, s in flat_s if "h2o_mass" in str(p)]
    assert mass, "paged H2O pools must carry per-page mass"
    for path, s in mass:
        np.testing.assert_allclose(np.asarray(flat_f[path]), np.asarray(s),
                                   rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# Config resolution
# ---------------------------------------------------------------------------

def test_fused_backend_resolution():
    assert not TwilightConfig(fused_backend="staged").use_fused_decode()
    assert TwilightConfig(fused_backend="fused").use_fused_decode()
    # "auto" fuses on TPU only; this container is CPU.
    assert TwilightConfig(fused_backend="auto").use_fused_decode() == (
        jax.default_backend() == "tpu")
    # Nothing to fuse / kernel cannot express the config -> staged.
    assert not TwilightConfig(fused_backend="fused",
                              prune_enabled=False).use_fused_decode()
    assert not TwilightConfig(fused_backend="fused",
                              estimate_bits=16).use_fused_decode()
    assert not TwilightConfig(
        fused_backend="fused",
        reuse_int4_for_attention=True).use_fused_decode()
    with pytest.raises(ValueError, match="fused_backend"):
        TwilightConfig(fused_backend="bogus").use_fused_decode()
