"""Prefix cache: a radix tree over page-granular token prefixes.

Real serving traffic is dominated by requests that share long system /
few-shot prefixes.  Because the paged pool keys all Twilight metadata by
*physical* page, a prefix that is already resident can be reused by any
number of requests simultaneously: the engine matches the longest cached
page-aligned prefix, takes a shared reference on those pages
(:meth:`~repro.serving.paged_cache.PageAllocator.share`), and prefills only
the suffix.

Structure: one tree level per page.  A node's key is the exact
``page_size``-token tuple written in its physical page; a path from the
root spells out a token prefix page by page, so lookup is a dict walk —
O(pages) with no scanning.  The tree owns one reference per indexed page;
requests stack their own references on top, and copy-on-write in the
engine keeps writers from ever mutating a page the tree (or another
reader) still sees.

Eviction is LRU over *leaf* nodes whose page refcount is exactly 1 (the
tree's own reference — no live reader).  Interior nodes become evictable
once their children are gone, so a cold chain drains tail-first;  pages
with live readers are never reclaimed, which is what makes preemption and
retirement decrement-only-safe.

Insertion is first-writer-wins: if a node for a page-key already exists
(two requests raced to prefill the same prefix), the existing physical
page is kept and the duplicate stays private to its request — refcounts
make both outcomes safe.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.serving.paged_cache import PageAllocator

__all__ = ["PrefixCache"]


@dataclasses.dataclass
class _Node:
    key: tuple[int, ...]  # the page_size tokens this page holds
    page: int  # physical page id
    parent: "_Node | None"
    children: dict[tuple[int, ...], "_Node"] = dataclasses.field(
        default_factory=dict)
    last_used: int = 0  # LRU tick


class PrefixCache:
    """Radix tree mapping page-granular token prefixes to physical pages."""

    def __init__(self, page_size: int, allocator: PageAllocator):
        self.page_size = page_size
        self.allocator = allocator
        self._root: dict[tuple[int, ...], _Node] = {}
        self._tick = 0
        self.n_nodes = 0

    # -- lookup -------------------------------------------------------------

    def match(self, tokens: np.ndarray) -> tuple[list[int], int]:
        """Longest cached page-aligned prefix of ``tokens``.

        Returns ``(pages, n_matched_tokens)`` and takes one shared
        reference per returned page — the caller owns those references and
        releases them with ``allocator.free`` (directly, or via request
        retirement).  Touches every node on the path for LRU.
        """
        ps = self.page_size
        level = self._root
        pages: list[int] = []
        self._tick += 1
        i = 0
        while i + ps <= len(tokens):
            node = level.get(tuple(int(t) for t in tokens[i:i + ps]))
            if node is None:
                break
            node.last_used = self._tick
            pages.append(node.page)
            i += ps
            level = node.children
        if pages:
            self.allocator.share(pages)
        return pages, i

    # -- insertion ----------------------------------------------------------

    def insert(self, tokens: np.ndarray, pages: list[int]) -> int:
        """Index the first ``len(pages)`` full pages of ``tokens``.

        ``pages[j]`` must hold exactly ``tokens[j*ps:(j+1)*ps]`` (the
        engine inserts a request's prompt pages once its prefill
        completes).  New nodes take one tree-owned reference on their page;
        existing nodes are kept untouched (first writer wins).  Returns the
        number of nodes created.
        """
        ps = self.page_size
        if len(pages) * ps > len(tokens):
            raise ValueError(
                f"{len(pages)} pages need {len(pages) * ps} tokens, "
                f"have {len(tokens)}")
        level = self._root
        parent: _Node | None = None
        created = 0
        self._tick += 1
        for j, page in enumerate(pages):
            key = tuple(int(t) for t in tokens[j * ps:(j + 1) * ps])
            node = level.get(key)
            if node is None:
                self.allocator.share([page])
                node = _Node(key=key, page=page, parent=parent,
                             last_used=self._tick)
                level[key] = node
                created += 1
                self.n_nodes += 1
            else:
                node.last_used = self._tick
            level = node.children
            parent = node
        return created

    # -- eviction -----------------------------------------------------------

    def _nodes(self) -> Iterator[_Node]:
        stack = list(self._root.values())
        while stack:
            node = stack.pop()
            yield node
            stack.extend(node.children.values())

    def reclaimable(self) -> int:
        """Pages the tree could return to the pool right now: refcount-1
        nodes whose entire subtree is also refcount-1 (whole cold chains
        drain tail-first; a live reader anywhere below pins the chain)."""

        def count(node: _Node) -> tuple[int, int]:
            """(drainable pages, subtree size) in one walk."""
            below = size = 0
            for c in node.children.values():
                d, s = count(c)
                below += d
                size += s
            drainable = (self.allocator.refcount(node.page) == 1
                         and below == size)
            return below + (1 if drainable else 0), size + 1

        return sum(count(r)[0] for r in self._root.values())

    def clear(self) -> int:
        """Release every tree-owned page reference (``DecodeEngine.reset``).

        Drains the whole tree leaf-first via :meth:`evict`; with no live
        readers this returns every indexed page to the pool.  Returns the
        number of pages released; raises if pinned pages remain (a live
        reader still holds references — clear() is only valid on a
        quiesced engine)."""
        freed = 0
        while self.n_nodes:
            got = self.evict(self.n_nodes)
            if not got:
                raise RuntimeError(
                    f"prefix cache has {self.n_nodes} pinned nodes — "
                    "live readers must retire before clear()")
            freed += got
        return freed

    def evict(self, want: int) -> int:
        """Reclaim up to ``want`` pages, LRU leaf first.

        Only leaves whose page refcount is 1 (tree-only — no live reader)
        are touched.  Each pass collects every evictable leaf and drains
        them in LRU order; evicting a leaf may expose its parent for the
        *next* pass (a parent's ``last_used`` is always >= its children's
        — every match touching a child touched it — so deferring parents
        preserves LRU order while keeping the walk O(passes * nodes), not
        O(want * nodes)).  Returns the pages actually returned to the pool.
        """
        freed = 0
        while freed < want:
            leaves = [n for n in self._nodes()
                      if not n.children
                      and self.allocator.refcount(n.page) == 1]
            if not leaves:
                break
            leaves.sort(key=lambda n: n.last_used)
            for victim in leaves:
                if freed >= want:
                    break
                level = (victim.parent.children if victim.parent is not None
                         else self._root)
                del level[victim.key]
                self.n_nodes -= 1
                self.allocator.free([victim.page])
                freed += 1
        return freed
