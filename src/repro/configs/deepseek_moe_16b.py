"""DeepSeek-MoE 16B [arXiv:2401.06066] — fine-grained MoE, 2 shared + 64
routed top-6 experts, MHA (kv = 16 = n_heads)."""

from repro.core.twilight import TwilightConfig
from repro.models.common import ArchType, MoEConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        arch_type=ArchType.MOE,
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab_size=102400,
        moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_expert=1408,
                      period=1),
        twilight=TwilightConfig(selector="quest", p=0.95),
        citation="arXiv:2401.06066",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=64,
        vocab_size=512,
        moe=MoEConfig(n_experts=4, top_k=2, n_shared=1, d_expert=64, period=1),
        twilight=TwilightConfig(selector="quest", p=0.9, page_size=8,
                                min_candidate=16),
    )
