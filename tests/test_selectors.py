"""Token Selector semantics (Quest, DS, Streaming, H2O, GQA union)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.selectors import (
    DoubleSparsitySelector,
    FullSelector,
    H2OSelector,
    QuestSelector,
    SelectionContext,
    StreamingSelector,
    build_page_meta,
    calibrate_ds_channels,
    group_union,
    topk_mask,
)


def _ctx(rng, b=2, n=256, hkv=2, d=64, page=16):
    K = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    return K, SelectionContext(
        keys=K,
        page_meta=build_page_meta(K, page),
        accum_scores=jnp.asarray(rng.random((b, hkv, n)), jnp.float32),
        length=None,
        ds_channels=calibrate_ds_channels(K, 8),
    )


def test_group_union():
    m = jnp.asarray([[[1, 0, 0], [0, 1, 0], [0, 0, 0], [0, 0, 1]]], bool)
    out = group_union(m, 2)  # 4 q heads -> 2 kv heads
    np.testing.assert_array_equal(
        np.asarray(out), [[[1, 1, 0], [0, 0, 1]]])


def test_topk_mask_count(rng):
    s = jnp.asarray(rng.normal(size=(4, 100)), jnp.float32)
    m = topk_mask(s, 10)
    assert (np.asarray(m).sum(-1) == 10).all()


def test_quest_page_granularity(rng):
    K, ctx = _ctx(rng)
    q = jnp.asarray(rng.normal(size=(2, 4, 64)), jnp.float32)
    mask = QuestSelector().select(q, ctx, budget=64)
    m = np.asarray(mask).reshape(2, 2, 16, 16)  # pages of 16
    page_any = m.any(-1)
    page_all = m.all(-1)
    np.testing.assert_array_equal(page_any, page_all)  # whole pages only


def test_quest_upper_bound_property(rng):
    """Quest's min/max metadata is a true per-page upper bound:
    UB(page) >= max over tokens in page of q·k.  (Selection can still miss
    the argmax when other pages' UBs overestimate harder — that is exactly
    the over-selection the Twilight pruner then cleans up.)"""
    K, ctx = _ctx(rng, b=1, hkv=1)
    q = jnp.asarray(rng.normal(size=(1, 1, 64)), jnp.float32)
    pm = ctx.page_meta
    qe = np.asarray(q)[0, 0]
    ub = np.maximum(qe * np.asarray(pm.kmax)[0, :, 0],
                    qe * np.asarray(pm.kmin)[0, :, 0]).sum(-1)  # (n_pages,)
    true_scores = np.asarray(
        jnp.einsum("bhd,bnhd->bhn", q, K))[0, 0].reshape(16, 16)
    assert (ub >= true_scores.max(-1) - 1e-4).all()

    # With a planted strong key (focused attention — the regime Quest is
    # built for) the argmax page must always be selected.
    for i in range(10):
        r = np.random.default_rng(100 + i)
        qi = jnp.asarray(r.normal(size=(1, 1, 64)), jnp.float32)
        Kp = np.asarray(K).copy()
        pos = int(r.integers(0, 256))
        Kp[0, pos, 0] = 3.0 * np.asarray(qi)[0, 0]
        ctx_p = ctx._replace(keys=jnp.asarray(Kp),
                             page_meta=build_page_meta(jnp.asarray(Kp), 16))
        mask = QuestSelector().select(qi, ctx_p, budget=64)
        assert np.asarray(mask)[0, 0, pos], f"missed planted needle at {pos}"


def test_ds_selects_high_score_tokens(rng):
    K, ctx = _ctx(rng)
    q = jnp.asarray(rng.normal(size=(2, 4, 64)), jnp.float32)
    mask = DoubleSparsitySelector().select(q, ctx, budget=32)
    counts = np.asarray(mask).sum(-1)
    assert (counts >= 32).all() and (counts <= 128).all()  # union of 2 heads


def test_streaming_sink_and_recent(rng):
    K, ctx = _ctx(rng)
    length = jnp.asarray([256, 200])
    ctx = ctx._replace(length=length)
    q = jnp.asarray(rng.normal(size=(2, 4, 64)), jnp.float32)
    mask = np.asarray(StreamingSelector(n_sink=4).select(q, ctx, budget=36))
    assert mask[0, 0, :4].all()  # sinks
    assert mask[0, 0, 224:256].all()  # recent window
    assert not mask[0, 0, 100]  # middle dropped
    assert not mask[1, 0, 200:].any()  # beyond length invalid


def test_h2o_includes_heavy_hitters(rng):
    K, ctx = _ctx(rng)
    heavy = ctx.accum_scores.at[:, :, 7].set(100.0)
    ctx = ctx._replace(accum_scores=heavy)
    q = jnp.asarray(rng.normal(size=(2, 4, 64)), jnp.float32)
    mask = np.asarray(H2OSelector().select(q, ctx, budget=32))
    assert mask[:, :, 7].all()


def test_full_selector_respects_length(rng):
    K, ctx = _ctx(rng)
    ctx = ctx._replace(length=jnp.asarray([256, 100]))
    q = jnp.asarray(rng.normal(size=(2, 4, 64)), jnp.float32)
    mask = np.asarray(FullSelector().select(q, ctx, budget=0))
    assert mask[0].all()
    assert mask[1, :, :100].all() and not mask[1, :, 100:].any()


@pytest.mark.parametrize("name", ["full", "quest", "ds", "streaming", "h2o"])
def test_registry(name):
    from repro.core.selectors import selector_from_name
    sel = selector_from_name(name)
    assert hasattr(sel, "select")
