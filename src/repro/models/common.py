"""Unified model configuration covering all assigned architecture families."""

from __future__ import annotations

import dataclasses
import enum
from typing import Literal

from repro.core.twilight import TwilightConfig

__all__ = [
    "ArchType",
    "MoEConfig",
    "SSMConfig",
    "XLSTMConfig",
    "ModelConfig",
    "block_pattern",
]


class ArchType(str, enum.Enum):
    DENSE = "dense"
    MOE = "moe"
    HYBRID = "hybrid"
    SSM = "ssm"
    AUDIO = "audio"
    VLM = "vlm"


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Fine-grained mixture of experts (DeepSeek-MoE style)."""

    n_experts: int
    top_k: int
    n_shared: int = 0  # always-active shared experts
    d_expert: int = 0  # per-expert FFN width (0 -> use d_ff)
    period: int = 1  # MoE every `period` layers (Jamba: 2), dense otherwise
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    dense_d_ff: int = 0  # FFN width of the non-MoE layers when period > 1


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 selective SSM (Jamba's recurrent block)."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block stack: mLSTM (matrix memory) + sLSTM (scalar memory)."""

    slstm_every: int = 8  # one sLSTM block per this many layers (7:1 ratio)
    proj_factor: float = 2.0  # mLSTM up-projection
    conv_kernel: int = 4


BlockKind = Literal["attn", "mamba", "mlstm", "slstm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config to rule all ten architectures.

    Only the fields relevant to an arch family are consulted by the model
    code; configs set the rest to their defaults.
    """

    name: str
    arch_type: ArchType
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 -> d_model // n_heads (qwen3 overrides to 128)

    # Attention details.
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False

    # MoE / SSM / xLSTM sub-configs (None when unused).
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    xlstm: XLSTMConfig | None = None

    # Hybrid interleave: one attention layer per `attn_period` layers
    # (Jamba: 8); remaining layers are Mamba.  0 -> all layers attention.
    attn_period: int = 0

    # Encoder-decoder (Seamless): number of encoder layers (0 = decoder-only).
    encoder_layers: int = 0

    # Modality frontend stub: embeddings are supplied by input_specs().
    frontend: Literal["none", "audio", "vision"] = "none"
    n_prefix_tokens: int = 0  # patch/frame prefix length consumed by the LM

    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    # The paper's technique, integrated as a first-class feature.
    twilight: TwilightConfig = dataclasses.field(default_factory=TwilightConfig)

    # Provenance (source paper / model card), kept for DESIGN/EXPERIMENTS.
    citation: str = ""

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        if self.n_heads % self.n_kv_heads:
            raise ValueError("n_heads must be divisible by n_kv_heads")

    @property
    def group_size(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding/LM-head
        shard evenly over the tensor axis (standard production padding;
        Seamless' 256206 and InternVL's 151655 are not 16-divisible).
        Logits beyond ``vocab_size`` are dead rows — the loss never selects
        them and the engine slices them off before sampling."""
        return -(-self.vocab_size // 256) * 256

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def block_pattern(cfg: ModelConfig) -> list[BlockKind]:
    """Per-layer block kinds for the full depth."""
    kinds: list[BlockKind] = []
    for i in range(cfg.n_layers):
        if cfg.xlstm is not None:
            every = cfg.xlstm.slstm_every
            kinds.append("slstm" if (i + 1) % every == 0 else "mlstm")
        elif cfg.attn_period and cfg.attn_period > 1:
            # Jamba: attention on layer index attn_period//2 within each
            # period (matches the released 1:7 interleave placement).
            kinds.append("attn" if i % cfg.attn_period == cfg.attn_period // 2
                         else "mamba")
        else:
            kinds.append("attn")
    return kinds
