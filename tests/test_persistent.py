"""Persistent serving sessions: engine-lifetime state, streaming API, H2O
in paged serving, and true recompute preemption.

Four layers, mirroring how the PR is built:

* core — the page-granular H2O path (``SelectionContext.page_mass``) over
  a shuffled physical pool matches the contiguous page-mass layout;
* engine/H2O — paged H2O decode (per-physical-page mass maintained by the
  jitted step) emits exactly the tokens the contiguous per-request oracle
  emits at ragged lengths;
* persistence — one engine serves successive ``generate()`` calls
  token-exactly vs fresh per-call engines while its radix tree accrues
  cross-call hits; ``submit()/step()/drain()`` stream results
  incrementally; ``reset()`` returns every page (allocator refcounts
  balance) and the engine serves again afterwards; a dry pool is reclaimed
  from cold tree pages at ``submit()`` time;
* preemption — a preempted *sampled* request resumes token-exact under
  true recompute preemption (teacher-forced replay), where the old
  restart-from-prompt redrew its continuation.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import (
    PageMeta,
    SelectionContext,
    TwilightConfig,
    quantize_int4,
    twilight_decode_attention,
)
from repro.serving import DecodeEngine, Request
from repro.serving.engine import _Pending


# ---------------------------------------------------------------------------
# Core: page-mass H2O — pooled physical pages == contiguous layout
# ---------------------------------------------------------------------------

def test_h2o_page_mass_paged_matches_contiguous(rng):
    """Same logical page mass behind a shuffled physical pool must select
    the same candidate set and produce allclose attention output."""
    b, hq, hkv, n, d, ps = 2, 8, 2, 256, 64, 16
    n_pages = n // ps
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    K = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    mass = rng.random((b, n_pages, hkv)).astype(np.float32)
    length = jnp.asarray([256, 180])

    num_pages = 1 + b * n_pages + 3
    perm = rng.permutation(np.arange(1, num_pages))
    pt = np.zeros((b, n_pages), np.int32)
    rows = num_pages * ps
    k_pool = np.asarray(rng.normal(size=(rows, hkv, d)), np.float32)
    v_pool = np.asarray(rng.normal(size=(rows, hkv, d)), np.float32)
    mass_pool = rng.random((num_pages, hkv)).astype(np.float32)  # junk init
    pmax_pool = np.zeros((num_pages, hkv, d), np.float32)
    pmin_pool = np.zeros((num_pages, hkv, d), np.float32)
    Knp, Vnp = np.asarray(K), np.asarray(V)
    i = 0
    for bb in range(b):
        for p in range(n_pages):
            phys = int(perm[i]); i += 1
            pt[bb, p] = phys
            k_pool[phys * ps:(phys + 1) * ps] = Knp[bb, p * ps:(p + 1) * ps]
            v_pool[phys * ps:(phys + 1) * ps] = Vnp[bb, p * ps:(p + 1) * ps]
            mass_pool[phys] = mass[bb, p]
            pmax_pool[phys] = Knp[bb, p * ps:(p + 1) * ps].max(0)
            pmin_pool[phys] = Knp[bb, p * ps:(p + 1) * ps].min(0)

    pm = PageMeta(kmax=jnp.asarray(np.stack([pmax_pool[pt[bb]]
                                             for bb in range(b)])),
                  kmin=jnp.asarray(np.stack([pmin_pool[pt[bb]]
                                             for bb in range(b)])),
                  page_size=ps)
    pm_pool = PageMeta(kmax=jnp.asarray(pmax_pool),
                       kmin=jnp.asarray(pmin_pool), page_size=ps)
    cfg = TwilightConfig(selector="h2o", p=0.9, candidate_frac=0.5,
                         page_size=ps, min_candidate=64)
    ref = twilight_decode_attention(
        q, K, V, cfg,
        ctx=SelectionContext(keys=K, page_meta=pm, accum_scores=None,
                             length=length, ds_channels=None,
                             page_mass=jnp.asarray(mass)),
        qkeys=quantize_int4(K), length=length)
    paged = twilight_decode_attention(
        q, jnp.asarray(k_pool), jnp.asarray(v_pool), cfg,
        ctx=SelectionContext(keys=jnp.asarray(k_pool), page_meta=pm_pool,
                             accum_scores=None, length=length,
                             ds_channels=None, page_table=jnp.asarray(pt),
                             page_mass=jnp.asarray(mass_pool)),
        qkeys=quantize_int4(jnp.asarray(k_pool)), length=length)
    np.testing.assert_array_equal(np.asarray(paged.indices),
                                  np.asarray(ref.indices))
    np.testing.assert_allclose(np.asarray(paged.out), np.asarray(ref.out),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Engine: H2O paged == contiguous per-request oracle at ragged lengths
# ---------------------------------------------------------------------------

def test_h2o_paged_engine_matches_contiguous(rng):
    """The jitted step maintains per-physical-page mass from the pruner's
    post-top-p weights; H2O continuous batching must emit exactly what the
    solo contiguous engine (page-mass cache rows) emits."""
    cfg = get_smoke_config("qwen2-1.5b")
    cfg = cfg.replace(twilight=dataclasses.replace(cfg.twilight,
                                                   selector="h2o"))
    reqs = [Request(uid=uid,
                    prompt=rng.integers(8, cfg.vocab_size, L
                                        ).astype(np.int32),
                    max_new_tokens=mn)
            for uid, (L, mn) in enumerate([(24, 5), (17, 3), (9, 4)])]
    solo = DecodeEngine(cfg, batch_size=1, cache_capacity=64, seed=7)
    paged = DecodeEngine(cfg, params=solo.params, batch_size=2,
                         cache_capacity=64, seed=7, paged=True)
    want = {r.uid: r.tokens for r in solo.generate(reqs)}
    got = {r.uid: r.tokens for r in paged.generate(reqs)}
    assert got == want


# ---------------------------------------------------------------------------
# Persistence: cross-call prefix reuse, streaming API, reset, dry-pool
# ---------------------------------------------------------------------------

def _prefixed_batch(rng, cfg, prefix, uids, tails, max_new=3):
    return [Request(uid=u,
                    prompt=np.concatenate(
                        [prefix,
                         rng.integers(8, cfg.vocab_size, t).astype(np.int32)]),
                    max_new_tokens=max_new)
            for u, t in zip(uids, tails)]


def test_persistent_engine_cross_call_prefix_reuse(rng):
    """One engine, three successive generate() calls sharing a prefix:
    every call is token-exact vs a fresh per-call engine, and calls 2..3
    hit the radix tree populated by call 1 (cross-call reuse — the whole
    point of hoisting the pool out of generate())."""
    cfg = get_smoke_config("qwen2-1.5b")
    prefix = rng.integers(8, cfg.vocab_size, 24).astype(np.int32)
    persist = DecodeEngine(cfg, batch_size=2, cache_capacity=64, seed=7,
                           paged=True, prefix_share=True)
    calls = [((0, 1), (9, 4)), ((2, 3), (6, 11)), ((4, 5), (5, 8))]
    for call, (uids, tails) in enumerate(calls):
        reqs = _prefixed_batch(rng, cfg, prefix, uids, tails)
        fresh = DecodeEngine(cfg, params=persist.params, batch_size=2,
                             cache_capacity=64, seed=7, paged=True)
        want = {r.uid: r.tokens for r in fresh.generate(reqs)}
        got = {r.uid: r.tokens for r in persist.generate(reqs)}
        assert got == want, f"call {call} diverged from the per-call oracle"
        if call > 0:
            assert persist.last_prefix_hits >= 2, \
                f"call {call} must hit the tree populated by earlier calls"
            assert persist.last_prefix_tokens >= 2 * (len(prefix) // 2)
    assert persist.session_prefix_hits >= 4
    assert persist.session_completed == 6


def test_submit_step_drain_streaming(rng):
    """The streaming API: feed a second batch between decode steps of the
    first, harvest incrementally; every request matches its solo run."""
    cfg = get_smoke_config("qwen2-1.5b")
    reqs = [Request(uid=i,
                    prompt=rng.integers(8, cfg.vocab_size, L
                                        ).astype(np.int32),
                    max_new_tokens=mn)
            for i, (L, mn) in enumerate([(24, 6), (17, 3), (13, 4), (9, 5)])]
    eng = DecodeEngine(cfg, batch_size=2, cache_capacity=64, seed=7,
                       paged=True)
    eng.submit(reqs[:2])
    got = {}
    eng.step()  # first batch in flight
    eng.submit(reqs[2:])  # fed between decode steps
    while eng.busy():
        eng.step()
        for r in eng.drain():
            got[r.uid] = r.tokens
    for r in eng.drain():
        got[r.uid] = r.tokens
    solo = DecodeEngine(cfg, params=eng.params, batch_size=1,
                        cache_capacity=64, seed=7)
    want = {r.uid: r.tokens for r in solo.generate(reqs)}
    assert got == want


def test_reset_balances_refcounts_and_engine_serves_again(rng):
    """reset() drops slots, queue, and every tree reference: the refcounts
    must balance exactly (a leak raises — conservation across admissions,
    COW, eviction, and tree inserts), the session is released, and the
    engine must serve fresh requests afterwards."""
    cfg = get_smoke_config("qwen2-1.5b")
    prefix = rng.integers(8, cfg.vocab_size, 24).astype(np.int32)
    eng = DecodeEngine(cfg, batch_size=2, cache_capacity=64, seed=7,
                       paged=True, prefix_share=True)
    eng.generate(_prefixed_batch(rng, cfg, prefix, (0, 1, 2), (9, 4, 6)))
    assert eng._alloc.available < eng._alloc.capacity, \
        "the tree must retain pages for the test to mean anything"
    eng.reset()  # raises on a refcount leak
    assert eng._alloc is None and eng._tree is None and eng._state is None
    # Mid-flight reset: submit, step once (requests in flight), reset.
    eng.submit(_prefixed_batch(rng, cfg, prefix, (3, 4), (5, 7), max_new=8))
    eng.step()
    eng.reset()
    assert not eng.busy()
    # And the engine still serves, token-exact vs a fresh oracle.
    reqs = _prefixed_batch(rng, cfg, prefix, (9,), (4,))
    fresh = DecodeEngine(cfg, params=eng.params, batch_size=2,
                         cache_capacity=64, seed=7, paged=True)
    want = {r.uid: r.tokens for r in fresh.generate(reqs)}
    got = {r.uid: r.tokens for r in eng.generate(reqs)}
    assert got == want


def test_submit_reclaims_dry_pool(rng):
    """A persistent engine whose pool is entirely tree-owned must reclaim
    cold refcount-1 pages at submit() time — before admission ever has to
    fall back to preemption."""
    cfg = get_smoke_config("qwen2-1.5b")
    ps = cfg.twilight.page_size
    eng = DecodeEngine(cfg, batch_size=1, cache_capacity=64, seed=7,
                       paged=True, prefix_share=True, num_pages=8)
    first = Request(uid=0,
                    prompt=rng.integers(8, cfg.vocab_size, 24
                                        ).astype(np.int32),
                    max_new_tokens=3)
    eng.generate([first])
    # Absorb the remaining free pages into the tree (cold entries), so the
    # pool is dry with every page at refcount 1 (tree-only).
    extra = eng._alloc.alloc(eng._alloc.available)
    toks = rng.integers(8, cfg.vocab_size, len(extra) * ps).astype(np.int32)
    eng._tree.insert(toks, extra)
    eng._alloc.free(extra)
    assert eng._alloc.available == 0
    evicted0 = eng.session_evictions
    nxt = Request(uid=1,
                  prompt=rng.integers(8, cfg.vocab_size, 24
                                      ).astype(np.int32),
                  max_new_tokens=3)
    eng.submit([nxt])
    assert eng.session_evictions > evicted0, \
        "submit() on a dry pool must reclaim cold tree pages"
    assert eng._alloc.available > 0
    got = {}
    while eng.busy():
        eng.step()
        for r in eng.drain():
            got[r.uid] = r.tokens
    assert set(got) == {1} and len(got[1]) == 3


# ---------------------------------------------------------------------------
# True recompute preemption: sampled victims resume token-exact
# ---------------------------------------------------------------------------

def test_preempted_sampled_request_token_exact(rng):
    """A tight pool forces preemption of a *sampling* request; under true
    recompute preemption (host-synced tokens + teacher-forced replay) its
    continuation must match the roomy-pool engine exactly — the old
    restart-from-prompt redrew it."""
    cfg = get_smoke_config("qwen2-1.5b")
    reqs = [Request(uid=i,
                    prompt=rng.integers(8, cfg.vocab_size, 17
                                        ).astype(np.int32),
                    max_new_tokens=20, greedy=False)
            for i in range(2)]
    roomy = DecodeEngine(cfg, batch_size=2, cache_capacity=40, seed=7,
                         paged=True)
    tight = DecodeEngine(cfg, params=roomy.params, batch_size=2,
                         cache_capacity=40, seed=7, paged=True, num_pages=9)
    want = {r.uid: r.tokens for r in roomy.generate(reqs)}
    got = {r.uid: r.tokens for r in tight.generate(reqs)}
    assert tight.last_preemptions > 0, "pool sizing must force preemption"
    assert got == want


def test_forced_replay_matches_unpreempted(rng):
    """White-box: a request re-admitted with a generated-token carry (as a
    preemption victim would be) replays teacher-forced and continues
    exactly — for every preemption point, greedy and sampled."""
    cfg = get_smoke_config("qwen2-1.5b")
    for greedy in (True, False):
        req = Request(uid=5,
                      prompt=rng.integers(8, cfg.vocab_size, 17
                                          ).astype(np.int32),
                      max_new_tokens=12, greedy=greedy)
        ref = DecodeEngine(cfg, batch_size=1, cache_capacity=40, seed=7,
                           paged=True)
        want = ref.generate([req])[0].tokens
        for k in (1, 4, 11):
            eng = DecodeEngine(cfg, params=ref.params, batch_size=1,
                               cache_capacity=40, seed=7, paged=True)
            eng._ensure_session([req])
            eng._pending.append(_Pending(req=req, generated=want[:k]))
            got = []
            while len(got) < 1:
                eng.step()
                got.extend(eng.drain({5}))
            assert got[0].tokens == want, (greedy, k)


def test_window_engine_matches_plain(rng):
    """``decode_window=4`` on a workload with no preemption: every step has
    exactly one queued token per slot, so the window path must reproduce
    the plain engine token for token (greedy AND sampled) — and its
    run-stats accumulator must agree with the plain engine's."""
    cfg = get_smoke_config("qwen2-1.5b")
    cfg = cfg.replace(twilight=dataclasses.replace(
        cfg.twilight, collect_run_stats=True))
    reqs = [Request(uid=i,
                    prompt=rng.integers(8, cfg.vocab_size, L
                                        ).astype(np.int32),
                    max_new_tokens=mn, greedy=greedy)
            for i, (L, mn, greedy) in enumerate(
                [(24, 6, True), (17, 4, False), (9, 3, True)])]
    plain = DecodeEngine(cfg, batch_size=2, cache_capacity=64, seed=7,
                         paged=True)
    win = DecodeEngine(cfg, params=plain.params, batch_size=2,
                       cache_capacity=64, seed=7, paged=True,
                       decode_window=4)
    want = {r.uid: r.tokens for r in plain.generate(reqs)}
    got = {r.uid: r.tokens for r in win.generate(reqs)}
    assert got == want
    rs_p, rs_w = plain.session_run_stats(), win.session_run_stats()
    assert rs_p is not None and rs_w is not None
    assert rs_p == rs_w


def test_window_engine_preemption_replay_token_exact(rng):
    """The multi-token bugfix: a preemption victim's teacher-forced replay
    goes through the k-token window path (up to ``decode_window`` queued
    tokens per launch) and must stay token-exact vs the solo oracle —
    while taking strictly fewer decode launches than one-per-token."""
    cfg = get_smoke_config("qwen2-1.5b")
    cfg = cfg.replace(twilight=dataclasses.replace(
        cfg.twilight, selector="full", candidate_frac=1.0))
    reqs = [Request(uid=i,
                    prompt=rng.integers(8, cfg.vocab_size, 17
                                        ).astype(np.int32),
                    max_new_tokens=20, greedy=False)
            for i in range(2)]
    roomy = DecodeEngine(cfg, batch_size=2, cache_capacity=40, seed=7,
                         paged=True)
    want = {r.uid: r.tokens for r in roomy.generate(reqs)}

    def run_tight(kw):
        eng = DecodeEngine(cfg, params=roomy.params, batch_size=2,
                           cache_capacity=40, seed=7, paged=True,
                           num_pages=9, decode_window=kw)
        eng.submit(reqs)
        got, steps = {}, 0
        while eng.busy():
            eng.step()
            steps += 1
            for r in eng.drain():
                got[r.uid] = r.tokens
        return got, steps, eng

    got1, steps1, eng1 = run_tight(1)
    got4, steps4, eng4 = run_tight(4)
    assert eng1.session_preemptions > 0, "pool sizing must force preemption"
    assert eng4.session_preemptions > 0
    assert got1 == want
    assert got4 == want
    assert steps4 < steps1, \
        "window replay must batch teacher-forced tokens into fewer launches"


def test_decode_window_requires_paged():
    cfg = get_smoke_config("qwen2-1.5b")
    with pytest.raises(ValueError, match="decode_window"):
        DecodeEngine(cfg, batch_size=1, cache_capacity=64, decode_window=4)
    with pytest.raises(ValueError, match="decode_window"):
        DecodeEngine(cfg, batch_size=1, cache_capacity=64, paged=True,
                     decode_window=0)


def test_step_drain_require_paged():
    cfg = get_smoke_config("qwen2-1.5b")
    eng = DecodeEngine(cfg, batch_size=1, cache_capacity=64)
    with pytest.raises(ValueError, match="paged"):
        eng.submit([Request(uid=0, prompt=np.arange(8, dtype=np.int32))])
    with pytest.raises(ValueError, match="paged"):
        eng.step()


def test_generate_skips_stale_buffered_result_for_reused_uid():
    """Streaming/wrapper mix with a reused uid: a finished-but-undrained
    result must not satisfy (or be returned by) a later generate() call
    under the same uid — it stays buffered for a later drain()."""
    cfg = get_smoke_config("qwen2-1.5b")
    eng = DecodeEngine(cfg, batch_size=1, cache_capacity=64, seed=7,
                       paged=True)
    p1 = np.arange(8, 20, dtype=np.int32)
    p2 = np.arange(30, 47, dtype=np.int32)
    eng.submit([Request(uid=7, prompt=p1, max_new_tokens=3)])
    while eng.busy():
        eng.step()  # uid 7 finishes, result left undrained
    res = eng.generate([Request(uid=7, prompt=p2, max_new_tokens=4)])
    assert len(res) == 1
    assert res[0].prompt_len == len(p2) and len(res[0].tokens) == 4
    stale = eng.drain()
    assert len(stale) == 1
    assert stale[0].prompt_len == len(p1) and len(stale[0].tokens) == 3


def test_generate_rejects_duplicate_uids():
    """Completion tracking is per-uid; two requests sharing a uid in one
    call would be indistinguishable — rejected up front."""
    cfg = get_smoke_config("qwen2-1.5b")
    eng = DecodeEngine(cfg, batch_size=1, cache_capacity=64, paged=True)
    reqs = [Request(uid=3, prompt=np.arange(8, 16, dtype=np.int32),
                    max_new_tokens=2)
            for _ in range(2)]
    with pytest.raises(ValueError, match="duplicate uids"):
        eng.generate(reqs)
