"""Compact-index pipeline vs the dense-mask oracle.

The compact Select→Prune→Attend path (index buffers, B0-scaled cost) must
reproduce the dense pipeline (n-length masks) bit-for-bit in set terms and
to fp32 allclose in outputs — for every selector, under GQA group-wise
budgets, including the ragged `length` edge case.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest  # noqa: F401  (parametrize)

from repro.core import (
    SelectionContext,
    TwilightConfig,
    build_page_meta,
    calibrate_ds_channels,
    selector_from_name,
    twilight_decode_attention,
)
from repro.core.selectors import indices_from_mask, indices_to_mask

SELECTORS = ("full", "quest", "double_sparsity", "streaming", "h2o")

# The shared `rng` fixture (conftest) is now per-test and order-independent,
# so the local fixed-stream override this file used to carry is gone.


def _setup(rng, b=2, hq=8, hkv=2, n=512, d=64):
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    K = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    return q, K, V


def _ctx(rng, K, length=None, page=16):
    b, n, hkv, _ = K.shape
    return SelectionContext(
        keys=K,
        page_meta=build_page_meta(K, page),
        accum_scores=jnp.asarray(rng.random((b, hkv, n)), jnp.float32),
        length=length,
        ds_channels=calibrate_ds_channels(K, 8),
    )


def _dense_vs_compact(q, K, V, cfg, ctx, length=None):
    dense = twilight_decode_attention(
        q, K, V, dataclasses.replace(cfg, compact=False), ctx=ctx,
        length=length)
    comp = twilight_decode_attention(
        q, K, V, dataclasses.replace(cfg, compact=True), ctx=ctx,
        length=length)
    return dense, comp


@pytest.mark.parametrize("selector", SELECTORS)
@pytest.mark.parametrize("ragged", [False, True])
def test_compact_matches_dense_oracle(rng, selector, ragged):
    q, K, V = _setup(rng)
    length = jnp.asarray([512, 300]) if ragged else None
    ctx = _ctx(rng, K, length=length)
    cfg = TwilightConfig(selector=selector, p=0.9, candidate_frac=0.5,
                         page_size=16, min_candidate=64)
    dense, comp = _dense_vs_compact(q, K, V, cfg, ctx, length=length)

    np.testing.assert_allclose(np.asarray(comp.out), np.asarray(dense.out),
                               rtol=1e-5, atol=1e-5)
    # Same candidate and pruned set sizes...
    np.testing.assert_array_equal(
        np.asarray(dense.stats.candidate_budget),
        np.asarray(comp.stats.candidate_budget))
    np.testing.assert_array_equal(
        np.asarray(dense.stats.pruned_budget),
        np.asarray(comp.stats.pruned_budget))
    # ...and the exact same sets once the index buffers are scattered back.
    n = K.shape[1]
    np.testing.assert_array_equal(
        np.asarray(indices_to_mask(comp.indices, comp.candidate_valid, n)),
        np.asarray(dense.candidate_mask))
    np.testing.assert_array_equal(
        np.asarray(indices_to_mask(comp.indices, comp.pruned_valid, n)),
        np.asarray(dense.pruned_mask))


@pytest.mark.parametrize("selector", ("quest", "streaming"))
def test_compact_prune_disabled_matches_dense(rng, selector):
    """Base-algorithm-only rows (pure top-k) agree between representations."""
    q, K, V = _setup(rng)
    ctx = _ctx(rng, K)
    cfg = TwilightConfig(selector=selector, prune_enabled=False,
                         fixed_budget=128, page_size=16)
    dense, comp = _dense_vs_compact(q, K, V, cfg, ctx)
    np.testing.assert_allclose(np.asarray(comp.out), np.asarray(dense.out),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(comp.pruned_valid),
                                  np.asarray(comp.candidate_valid))


def test_compact_fp16_estimate_matches_dense(rng):
    """estimate_bits=16 (no quantization) exercises the fp gather path."""
    q, K, V = _setup(rng)
    ctx = _ctx(rng, K)
    cfg = TwilightConfig(selector="quest", p=0.9, candidate_frac=0.5,
                         page_size=16, min_candidate=64, estimate_bits=16)
    dense, comp = _dense_vs_compact(q, K, V, cfg, ctx)
    np.testing.assert_allclose(np.asarray(comp.out), np.asarray(dense.out),
                               rtol=1e-5, atol=1e-5)


def test_pruned_cap_generous_is_exact(rng):
    """A cap above the kept count re-compacts without changing the output."""
    q, K, V = _setup(rng)
    # Make the group's queries near-identical and plant needle keys aligned
    # with them, so every query head focuses hard and top-p keeps a small
    # set (the regime the cap is sized for).
    b, n, hkv, d = K.shape
    qn = np.asarray(q).reshape(b, hkv, -1, d)
    qn = qn.mean(2, keepdims=True) + 0.05 * qn
    q = jnp.asarray(qn.reshape(b, -1, d), jnp.float32)
    qk = qn.mean(2)
    Kn = np.array(K)
    for i in range(b):
        for h in range(hkv):
            Kn[i, 31 + 13 * h, h] = 6.0 * qk[i, h]
    K = jnp.asarray(Kn)
    ctx = _ctx(rng, K)
    base = TwilightConfig(selector="full", p=0.9, candidate_frac=1.0,
                          page_size=16)
    ref = twilight_decode_attention(q, K, V, base, ctx=ctx)
    kept_max = int(np.asarray(ref.stats.pruned_budget).max())
    m = ref.indices.shape[-1]
    assert kept_max < m // 2  # focused attention keeps a small set
    capped = twilight_decode_attention(
        q, K, V, dataclasses.replace(base, pruned_cap_frac=0.5), ctx=ctx)
    np.testing.assert_allclose(np.asarray(capped.out), np.asarray(ref.out),
                               rtol=1e-5, atol=1e-5)


def test_pruned_cap_overflow_keeps_top_weights(rng):
    """Overflow drops lowest-weight kept slots: output stays finite and the
    attended count is exactly the cap."""
    q, K, V = _setup(rng)
    ctx = _ctx(rng, K)
    cfg = TwilightConfig(selector="full", p=0.999, candidate_frac=1.0,
                         page_size=16, pruned_cap_frac=0.25)
    out = twilight_decode_attention(q, K, V, cfg, ctx=ctx)
    assert np.isfinite(np.asarray(out.out)).all()
    # p=0.999 on diffuse random attention keeps nearly everything, so the
    # cap must actually bind.
    assert int(np.asarray(out.stats.pruned_budget).min()) > cfg.pruned_capacity(
        out.indices.shape[-1])


def test_compact_pallas_backend_matches_jnp(rng):
    """attn_backend="pallas" (interpret on CPU) == the jnp reference."""
    q, K, V = _setup(rng, n=256)
    ctx = _ctx(rng, K)
    cfg = TwilightConfig(selector="quest", p=0.9, candidate_frac=0.5,
                         page_size=16, min_candidate=64, attn_backend="jnp")
    ref = twilight_decode_attention(q, K, V, cfg, ctx=ctx)
    pal = twilight_decode_attention(
        q, K, V, dataclasses.replace(cfg, attn_backend="pallas"), ctx=ctx)
    np.testing.assert_allclose(np.asarray(pal.out), np.asarray(ref.out),
                               rtol=1e-4, atol=1e-4)


def test_indices_roundtrip(rng):
    mask = jnp.asarray(rng.random((3, 2, 200)) < 0.3)
    idx, valid = indices_from_mask(mask, 128)
    # Enough capacity: exact roundtrip.
    np.testing.assert_array_equal(
        np.asarray(indices_to_mask(idx, valid, 200)), np.asarray(mask))
    # Valid slots are ascending positions; dead slots are zero.
    iv = np.asarray(idx)
    vv = np.asarray(valid)
    for b in range(3):
        for h in range(2):
            live = iv[b, h][vv[b, h]]
            assert (np.diff(live) > 0).all()
            assert (iv[b, h][~vv[b, h]] == 0).all()


def test_quest_indices_page_aligned(rng):
    q, K, V = _setup(rng, n=256)
    ctx = _ctx(rng, K, page=16)
    sel = selector_from_name("quest")
    idx, valid = sel.select_indices(q, ctx, 64)
    assert idx.shape[-1] % 16 == 0  # whole pages
    iv = np.asarray(idx).reshape(*idx.shape[:-1], -1, 16)
    # Each page block covers a contiguous aligned page.
    assert (iv % 16 == np.arange(16)).all()
    # And matches the dense mask exactly.
    mask = sel.select(q, ctx, 64)
    np.testing.assert_array_equal(
        np.asarray(indices_to_mask(idx, valid, 256)), np.asarray(mask))
