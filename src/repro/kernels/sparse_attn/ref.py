"""Pure-jnp oracle for the sparse decode attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.topp import masked_softmax


def sparse_decode_attention_ref(
    q: jax.Array,  # (B, group, d)
    keys: jax.Array,  # (B, n, d)
    values: jax.Array,  # (B, n, d)
    mask: jax.Array,  # (B, n) bool
    *,
    sm_scale: float,
) -> jax.Array:
    s = jnp.einsum(
        "bgd,bnd->bgn", q.astype(jnp.float32), keys.astype(jnp.float32)
    ) * sm_scale
    w = masked_softmax(s, mask[:, None, :].astype(bool))
    out = jnp.einsum("bgn,bnd->bgd", w, values.astype(jnp.float32))
    return out.astype(q.dtype)
