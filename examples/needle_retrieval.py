"""Long-context retrieval under sparse attention: trains a small model on
the needle task, then compares Full / Quest-top-k / Quest+Twilight on
retrieval accuracy and attention budget — the paper's Tables 2/3 story in
miniature.

    PYTHONPATH=src python examples/needle_retrieval.py
"""


import numpy as np

from benchmarks.common import eval_needle_acc, needle_model, twilight_variant
from repro.data import DataConfig, needle_batch


def main():
    cfg, params = needle_model()
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=160, global_batch=32,
                      seed=42)
    rng = np.random.default_rng(42)
    batch = needle_batch(dcfg, rng, 32)

    rows = [
        ("full attention", twilight_variant(cfg, enabled=False)),
        ("quest top-k=16", twilight_variant(cfg, selector="quest",
                                            prune_enabled=False,
                                            fixed_budget=16)),
        ("quest top-k=96", twilight_variant(cfg, selector="quest",
                                            prune_enabled=False,
                                            fixed_budget=96)),
        ("quest + twilight p=.95", twilight_variant(
            cfg, selector="quest", p=0.95, candidate_frac=0.5)),
    ]
    print(f"{'method':24s} {'retrieval acc':>13s} {'budget':>7s}")
    for name, c in rows:
        acc, budget = eval_needle_acc(params, c, batch)
        print(f"{name:24s} {acc:13.3f} {budget:7.1f}")


if __name__ == "__main__":
    main()
