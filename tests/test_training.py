"""Training substrate: optimizer math, schedules, loss decrease, grad accum."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data import DataConfig, synthetic_lm_batches
from repro.models import init_params
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.training import TrainConfig, make_train_step, train_loop


def test_adamw_against_reference():
    """One step on a scalar matches hand-computed AdamW."""
    cfg = AdamWConfig(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                      clip_norm=1e9)
    params = {"w": jnp.asarray([2.0])}
    grads = {"w": jnp.asarray([0.5])}
    state = adamw_init(params)
    new_p, state, _ = adamw_update(cfg, grads, state, params, lr=0.1)
    m = 0.1 * 0.5
    v = 0.001 * 0.25
    mh, vh = m / 0.1, v / 0.001
    expected = 2.0 - 0.1 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(float(new_p["w"][0]), expected, rtol=1e-5)


def test_grad_clipping():
    cfg = AdamWConfig(clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((3,))}
    grads = {"w": jnp.asarray([30.0, 40.0, 0.0])}  # norm 50
    state = adamw_init(params)
    _, state, metrics = adamw_update(cfg, grads, state, params, lr=0.0)
    np.testing.assert_allclose(float(metrics["grad_norm"]), 50.0, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(state["m"]["w"]),
                               [0.1 * 30 / 50, 0.1 * 40 / 50, 0.0], rtol=1e-5)


def test_cosine_schedule_shape():
    lrs = [float(cosine_schedule(s, 10, 100, 1.0)) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0  # warmup
    assert abs(max(lrs) - 1.0) < 1e-3
    assert lrs[-1] < 0.2  # decayed toward floor


def test_loss_decreases_quickly():
    cfg = get_smoke_config("qwen2-1.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8,
                      seed=0)
    tcfg = TrainConfig(peak_lr=3e-3, warmup_steps=3, total_steps=30,
                       remat=False)
    params, history = train_loop(params, cfg, tcfg,
                                 synthetic_lm_batches(dcfg, 30),
                                 log_every=29)
    assert history[-1]["loss"] < history[0]["loss"] - 0.3, history


def test_grad_accum_equivalence():
    """grad_accum=2 == one step on the full batch (same grads, fp tolerance)."""
    cfg = get_smoke_config("qwen2-1.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32))),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32))),
    }
    from repro.optim import adamw_init
    step1 = make_train_step(cfg, TrainConfig(remat=False, grad_accum=1,
                                             z_loss=0.0))
    step2 = make_train_step(cfg, TrainConfig(remat=False, grad_accum=2,
                                             z_loss=0.0))
    p1, _, m1 = step1(params, adamw_init(params), batch)
    p2, _, m2 = step2(params, adamw_init(params), batch)
    np.testing.assert_allclose(float(m1["ce"]), float(m2["ce"]), rtol=1e-3)
    l1 = jax.tree_util.tree_leaves(p1)
    l2 = jax.tree_util.tree_leaves(p2)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-3)


def test_vision_loss_masks_prefix():
    cfg = get_smoke_config("internvl2-1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 24))),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 24))),
        "patches": jnp.asarray(
            rng.normal(size=(2, cfg.n_prefix_tokens, cfg.d_model)),
            jnp.float32),
    }
    from repro.training.loop import loss_fn
    loss, metrics = loss_fn(params, cfg, batch, remat=False, z_loss=0.0)
    assert np.isfinite(float(loss))
