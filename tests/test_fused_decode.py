"""Fused single-launch decode kernel vs the staged compact pipeline.

The fused kernel (``kernels/fused_decode``) runs estimate → top-p → sparse
attention as ONE Pallas launch.  The staged compact pipeline is the
equivalence oracle; for apples-to-apples numerics the staged estimate is
pinned to the spgemv backend (``estimate_backend="pallas"``) so both sides
compute scores in f32 code space, and ``pruned_cap_frac=1.0`` so the
staged path attends the full kept set exactly as the fused kernel does.

Levels, mirroring how the feature is layered:

* op — ``fused_prune_attend`` vs the pure-jnp ``fused_prune_attend_ref``;
* core — ``twilight_decode_attention`` fused vs staged for every selector,
  contiguous and paged (shuffled pool + page tables), ragged lengths;
* engine — paged continuous batching emits token-identical results fused
  vs staged, greedy AND sampled, including H2O (whose page-mass feed is
  the fused kernel's ``slot_weights`` output — asserted bit-equal on the
  pool accumulator).

Plus the top-p edge cases for both kernels: p→0 (budget collapses to the
argmax slot per query head), p=1.0 (keeps every valid candidate),
fully-masked rows, and a candidate budget smaller than one page.
"""

import dataclasses
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import (
    SelectionContext,
    TwilightConfig,
    build_page_meta,
    calibrate_ds_channels,
    quantize_int4,
    twilight_decode_attention,
)
from repro.core import runs as runs_lib
from repro.kernels.fused_decode.kernel import coalesce_block
from repro.kernels.fused_decode.ops import (
    FUSED_VMEM_BUDGET,
    fused_fits,
    fused_prune_attend,
    fused_prune_attend_window,
    fused_vmem_bytes,
)
from repro.kernels.fused_decode.ref import (
    fused_prune_attend_ref,
    fused_prune_attend_window_ref,
)
from repro.serving import DecodeEngine, Request
from tests.test_paged_cache import _paged_fixture

SELECTORS = ("full", "quest", "double_sparsity", "streaming", "h2o")


def _cfg(selector="quest", fused="staged", **kw):
    """Staged/fused config pair base: identical numerics on both paths."""
    kw.setdefault("p", 0.9)
    kw.setdefault("candidate_frac", 0.5)
    kw.setdefault("page_size", 16)
    kw.setdefault("min_candidate", 64)
    return TwilightConfig(selector=selector, estimate_backend="pallas",
                          pruned_cap_frac=1.0, fused_backend=fused, **kw)


def _setup(rng, b=2, hq=8, hkv=2, n=512, d=64):
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    K = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    return q, K, V


def _ctx(rng, K, length=None, page=16):
    b, n, hkv, _ = K.shape
    return SelectionContext(
        keys=K,
        page_meta=build_page_meta(K, page),
        accum_scores=jnp.asarray(rng.random((b, hkv, n)), jnp.float32),
        length=length,
        ds_channels=calibrate_ds_channels(K, 8),
    )


def _assert_fused_matches_staged(fused, staged, *, out_tol=1e-4):
    np.testing.assert_array_equal(np.asarray(fused.pruned_valid),
                                  np.asarray(staged.pruned_valid))
    np.testing.assert_array_equal(np.asarray(fused.candidate_valid),
                                  np.asarray(staged.candidate_valid))
    np.testing.assert_array_equal(np.asarray(fused.stats.candidate_budget),
                                  np.asarray(staged.stats.candidate_budget))
    np.testing.assert_array_equal(np.asarray(fused.stats.pruned_budget),
                                  np.asarray(staged.stats.pruned_budget))
    np.testing.assert_allclose(np.asarray(fused.slot_weights),
                               np.asarray(staged.slot_weights),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(fused.stats.threshold),
                               np.asarray(staged.stats.threshold),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(fused.out), np.asarray(staged.out),
                               rtol=out_tol, atol=out_tol)


# ---------------------------------------------------------------------------
# Op level: kernel vs the pure-jnp reference
# ---------------------------------------------------------------------------

def test_fused_op_matches_ref(rng):
    q, K, V = _setup(rng, n=256)
    b, n, hkv, d = K.shape
    m = 128
    qkeys = quantize_int4(K)
    idx = jnp.asarray(np.sort(rng.choice(n, size=(b, hkv, m)), -1), jnp.int32)
    valid = jnp.asarray(rng.random((b, hkv, m)) < 0.9)
    idx = jnp.where(valid, idx, 0)
    out, kept, w, th = fused_prune_attend(q, idx, valid, K, V, qkeys, p=0.9)
    ro, rk, rw, rt = fused_prune_attend_ref(q, idx, valid, K, V, qkeys, p=0.9)
    np.testing.assert_array_equal(np.asarray(kept), np.asarray(rk))
    np.testing.assert_allclose(np.asarray(w), np.asarray(rw),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(th), np.asarray(rt),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ro),
                               rtol=1e-4, atol=1e-5)


def test_fused_op_all_masked_row_emits_zeros(rng):
    """A fully-invalid candidate row (dead engine slot) keeps nothing and
    outputs exact zeros — in the kernel AND the staged pruner."""
    q, K, V = _setup(rng, n=256)
    b, n, hkv, d = K.shape
    m, group = 128, q.shape[1] // hkv
    qkeys = quantize_int4(K)
    idx = jnp.asarray(np.sort(rng.choice(n, size=(b, hkv, m)), -1), jnp.int32)
    valid = jnp.asarray(rng.random((b, hkv, m)) < 0.9).at[0, 0].set(False)
    idx = jnp.where(valid, idx, 0)
    out, kept, w, th = fused_prune_attend(q, idx, valid, K, V, qkeys, p=0.9)
    assert not np.asarray(kept)[0, 0].any()
    assert (np.asarray(w)[0, 0] == 0).all()
    np.testing.assert_array_equal(np.asarray(out)[0, :group], 0.0)
    # Staged: same dead row through prune_at.
    pruner = _cfg().make_pruner()
    kept_s, _, w_s = pruner.prune_at(q, idx, valid, keys=K, qkeys=qkeys)
    assert not np.asarray(kept_s)[0, 0].any()
    assert (np.asarray(w_s)[0, 0] == 0).all()


# ---------------------------------------------------------------------------
# Core: fused pipeline vs staged pipeline, contiguous and paged
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("selector", SELECTORS)
@pytest.mark.parametrize("ragged", [False, True])
def test_fused_pipeline_matches_staged(rng, selector, ragged):
    q, K, V = _setup(rng)
    length = jnp.asarray([512, 300]) if ragged else None
    ctx = _ctx(rng, K, length=length)
    staged = twilight_decode_attention(
        q, K, V, _cfg(selector, "staged"), ctx=ctx, length=length)
    fused = twilight_decode_attention(
        q, K, V, _cfg(selector, "fused"), ctx=ctx, length=length)
    _assert_fused_matches_staged(fused, staged)


@pytest.mark.parametrize("selector", SELECTORS)
def test_fused_pipeline_matches_staged_paged(rng, selector):
    """Shuffled physical pool + page tables: the fused kernel DMAs from the
    pool at pre-translated physical rows, exactly like the staged gathers."""
    fx = _paged_fixture(rng)
    length = jnp.asarray([256, 180])
    kw = dict(candidate_frac=0.5, min_candidate=64)
    staged = twilight_decode_attention(
        fx["q"], fx["k_pool"], fx["v_pool"], _cfg(selector, "staged", **kw),
        ctx=fx["ctx_paged"](length), qkeys=fx["qkeys_pool"], length=length)
    fused = twilight_decode_attention(
        fx["q"], fx["k_pool"], fx["v_pool"], _cfg(selector, "fused", **kw),
        ctx=fx["ctx_paged"](length), qkeys=fx["qkeys_pool"], length=length)
    _assert_fused_matches_staged(fused, staged)


def test_fused_budget_below_one_page(rng):
    """B0 smaller than one page: the page-granular selector still emits one
    whole page and both paths agree (incl. the dense oracle)."""
    q, K, V = _setup(rng, n=256)
    ctx = _ctx(rng, K)
    kw = dict(fixed_budget=8, candidate_frac=0.25, min_candidate=1)
    staged = twilight_decode_attention(q, K, V, _cfg("quest", "staged", **kw),
                                       ctx=ctx)
    fused = twilight_decode_attention(q, K, V, _cfg("quest", "fused", **kw),
                                      ctx=ctx)
    assert int(np.asarray(staged.stats.candidate_budget).max()) <= 16
    _assert_fused_matches_staged(fused, staged)
    dense = twilight_decode_attention(
        q, K, V, dataclasses.replace(_cfg("quest", "staged", **kw),
                                     compact=False), ctx=ctx)
    np.testing.assert_allclose(np.asarray(fused.out), np.asarray(dense.out),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Top-p edge cases, fused and staged
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["staged", "fused"])
def test_p_to_zero_collapses_to_argmax(rng, backend):
    """p→0: the binary search converges to max(w), so each query head keeps
    exactly its argmax slot; the loaded set is the group union of argmaxes."""
    q, K, V = _setup(rng)
    ctx = _ctx(rng, K)
    out = twilight_decode_attention(
        q, K, V, _cfg("quest", backend, p=1e-9), ctx=ctx)
    b, hkv, _ = out.pruned_valid.shape
    group = q.shape[1] // hkv
    budgets = np.asarray(out.stats.pruned_budget)
    assert (budgets >= 1).all() and (budgets <= group).all()


def test_p_to_zero_fused_matches_staged(rng):
    q, K, V = _setup(rng)
    ctx = _ctx(rng, K)
    staged = twilight_decode_attention(q, K, V, _cfg("quest", "staged",
                                                     p=1e-9), ctx=ctx)
    fused = twilight_decode_attention(q, K, V, _cfg("quest", "fused",
                                                    p=1e-9), ctx=ctx)
    _assert_fused_matches_staged(fused, staged)


@pytest.mark.parametrize("backend", ["staged", "fused"])
def test_p_one_keeps_all_valid(rng, backend):
    """p=1.0: no threshold below the full mass exists, so every valid
    candidate survives (thresholds may differ in the last ulp between
    backends — the *set* semantics are what is pinned here)."""
    q, K, V = _setup(rng)
    length = jnp.asarray([512, 300])
    ctx = _ctx(rng, K, length=length)
    out = twilight_decode_attention(
        q, K, V, _cfg("quest", backend, p=1.0), ctx=ctx, length=length)
    np.testing.assert_array_equal(np.asarray(out.pruned_valid),
                                  np.asarray(out.candidate_valid))


# ---------------------------------------------------------------------------
# Engine: fused serving is token-exact vs staged, greedy and sampled
# ---------------------------------------------------------------------------

def _serving_cfg(selector="quest", fused="staged"):
    cfg = get_smoke_config("qwen2-1.5b")
    return cfg.replace(twilight=dataclasses.replace(
        cfg.twilight, selector=selector, estimate_backend="pallas",
        pruned_cap_frac=1.0, fused_backend=fused))


def test_engine_fused_matches_staged_greedy_and_sampled(rng):
    reqs = []
    cfg_s = _serving_cfg("quest", "staged")
    for uid, (L, mn, greedy) in enumerate([(24, 5, True), (17, 4, False),
                                           (9, 3, True), (13, 4, False)]):
        reqs.append(Request(
            uid=uid, prompt=rng.integers(8, cfg_s.vocab_size, L
                                         ).astype(np.int32),
            max_new_tokens=mn, greedy=greedy))
    staged = DecodeEngine(cfg_s, batch_size=2, cache_capacity=64, seed=7,
                          paged=True)
    fused = DecodeEngine(_serving_cfg("quest", "fused"), params=staged.params,
                         batch_size=2, cache_capacity=64, seed=7, paged=True)
    want = {r.uid: r.tokens for r in staged.generate(reqs)}
    got = {r.uid: r.tokens for r in fused.generate(reqs)}
    assert got == want


def test_engine_fused_h2o_token_exact_with_mass_parity(rng):
    """Paged H2O fed by the fused kernel's ``slot_weights``: tokens AND the
    per-physical-page mass accumulator must match the staged engine."""
    cfg_s = _serving_cfg("h2o", "staged")
    reqs = [Request(uid=uid,
                    prompt=rng.integers(8, cfg_s.vocab_size, L
                                        ).astype(np.int32),
                    max_new_tokens=mn)
            for uid, (L, mn) in enumerate([(24, 5), (17, 3), (9, 4)])]
    staged = DecodeEngine(cfg_s, batch_size=2, cache_capacity=64, seed=7,
                          paged=True)
    fused = DecodeEngine(_serving_cfg("h2o", "fused"), params=staged.params,
                         batch_size=2, cache_capacity=64, seed=7, paged=True)
    want = {r.uid: r.tokens for r in staged.generate(reqs)}
    got = {r.uid: r.tokens for r in fused.generate(reqs)}
    assert got == want
    flat_s = jax.tree_util.tree_leaves_with_path(staged._state)
    flat_f = dict(jax.tree_util.tree_leaves_with_path(fused._state))
    mass = [(p, s) for p, s in flat_s if "h2o_mass" in str(p)]
    assert mass, "paged H2O pools must carry per-page mass"
    for path, s in mass:
        np.testing.assert_allclose(np.asarray(flat_f[path]), np.asarray(s),
                                   rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# Config resolution
# ---------------------------------------------------------------------------

def test_fused_backend_resolution():
    assert not TwilightConfig(fused_backend="staged").use_fused_decode()
    assert TwilightConfig(fused_backend="fused").use_fused_decode()
    # "auto" fuses on TPU only; this container is CPU.
    assert TwilightConfig(fused_backend="auto").use_fused_decode() == (
        jax.default_backend() == "tpu")
    # Nothing to fuse / kernel cannot express the config -> staged.
    assert not TwilightConfig(fused_backend="fused",
                              prune_enabled=False).use_fused_decode()
    assert not TwilightConfig(fused_backend="fused",
                              estimate_bits=16).use_fused_decode()
    assert not TwilightConfig(
        fused_backend="fused",
        reuse_int4_for_attention=True).use_fused_decode()
    with pytest.raises(ValueError, match="fused_backend"):
        TwilightConfig(fused_backend="bogus").use_fused_decode()


# ---------------------------------------------------------------------------
# Run coalescing: RLE reference properties + jit-safe telemetry
# ---------------------------------------------------------------------------

_PS = 16


def _kept_patterns(rng, m=96):
    """Adversarial survivor bitmaps over an m-slot candidate buffer."""
    alternating = np.zeros(m, bool)
    alternating[::2] = True
    single = np.zeros(m, bool)
    single[m // 3] = True
    all_kept = np.ones(m, bool)
    tail_empty = np.ones(m, bool)
    tail_empty[-_PS:] = False  # last page entirely dropped
    random = rng.random(m) < 0.4
    return {
        "alternating": alternating,
        "single_survivor": single,
        "all_kept": all_kept,
        "empty_tail_page": tail_empty,
        "random": random,
    }


@pytest.mark.parametrize("contiguous_idx", [True, False])
def test_coalesced_runs_properties(rng, contiguous_idx):
    """Runs partition the kept set, are index-contiguous, and never cross
    a page boundary — for every adversarial bitmap, with both densely
    consecutive and gappy candidate indices."""
    m = 96
    if contiguous_idx:
        idx = np.arange(m, dtype=np.int32)
    else:
        idx = np.sort(rng.choice(4 * m, size=m, replace=False)).astype(
            np.int32)
    for name, kept in _kept_patterns(rng, m).items():
        runs = runs_lib.coalesced_runs(kept, idx, _PS)
        covered = np.zeros(m, bool)
        for start, length in runs:
            assert length >= 1, name
            sl = slice(start, start + length)
            assert not covered[sl].any(), f"{name}: overlapping runs"
            covered[sl] = True
            assert kept[sl].all(), f"{name}: run covers a dropped slot"
            # index-contiguous within the run
            np.testing.assert_array_equal(
                idx[sl], np.arange(idx[start], idx[start] + length),
                err_msg=f"{name}: non-consecutive indices inside a run")
            # one physical page per run
            assert idx[start] // _PS == idx[start + length - 1] // _PS, (
                f"{name}: run crosses a page boundary")
        np.testing.assert_array_equal(covered, kept,
                                      err_msg=f"{name}: runs != kept set")


def test_run_length_stats_matches_rle_reference(rng):
    """The jit-safe aggregate equals the numpy RLE, bitmap by bitmap."""
    b, hkv, m = 2, 3, 96
    n_pages = (4 * m) // _PS + 1
    kept = np.stack([np.stack(list(_kept_patterns(rng, m).values())[:hkv])
                     for _ in range(b)])
    idx = np.sort(rng.choice(4 * m, size=(b, hkv, m)), axis=-1).astype(
        np.int32)
    # de-dup so "consecutive" is well defined (sorted unique per row)
    for i in range(b):
        for h in range(hkv):
            row = np.unique(idx[i, h])
            idx[i, h, :len(row)] = row
            idx[i, h, len(row):] = np.arange(4 * m, 4 * m + m - len(row))
    got = np.asarray(runs_lib.run_length_stats(
        jnp.asarray(kept), jnp.asarray(idx), _PS, n_pages))
    want = np.zeros(runs_lib.RUN_STATS_LEN)
    for i in range(b):
        for h in range(hkv):
            runs = runs_lib.coalesced_runs(kept[i, h], idx[i, h], _PS)
            for _, length in runs:
                bucket = min(int(np.floor(np.log2(length))),
                             runs_lib.RUN_HIST_BUCKETS - 1)
                want[bucket] += 1
            want[runs_lib.RUN_HIST_BUCKETS] += len(runs)
            want[runs_lib.RUN_HIST_BUCKETS + 1] += len(
                {int(x) // _PS for x in idx[i, h][kept[i, h]]})
            want[runs_lib.RUN_HIST_BUCKETS + 2] += int(kept[i, h].sum())
    np.testing.assert_array_equal(got, want)


def test_summarize_run_stats_arithmetic():
    vec = np.zeros(runs_lib.RUN_STATS_LEN)
    vec[:3] = [4, 2, 1]  # 7 runs in the histogram
    hb = runs_lib.RUN_HIST_BUCKETS
    vec[hb:hb + 3] = [7, 5, 21]
    s = runs_lib.summarize_run_stats(vec, steps=7)
    assert s["steps"] == 7
    assert s["run_hist"][:3] == [4, 2, 1]
    assert s["runs_per_step"] == 1.0
    assert s["pages_per_step"] == 5 / 7
    assert s["kept_per_step"] == 3.0
    assert s["mean_run_len"] == 3.0
    # Sections past the legacy triple stay zeroed on a flat decode vector.
    assert s["cand_pages_per_step"] == 0.0
    assert s["prefill_pages_live"] == 0.0
    assert s["prefill_live_frac"] == 0.0


# ---------------------------------------------------------------------------
# VMEM budget arithmetic: staging + k-token accumulator scaling
# ---------------------------------------------------------------------------

def test_fused_vmem_staging_term():
    """The kv_bytes-dependent term is exactly the double-buffered two-stream
    staging scratch: 2 buffers x 2 (K and V) x blk rows x d x kv_bytes."""
    m, d, group, ps = 1024, 128, 8, 64
    blk = coalesce_block(m, ps)
    delta = (fused_vmem_bytes(m, d, group, kv_bytes=2, page_size=ps)
             - fused_vmem_bytes(m, d, group, kv_bytes=1, page_size=ps))
    assert delta == 2 * 2 * blk * d


def test_fused_vmem_k_scaling():
    """Each extra window position adds its bitmaps/weight rows plus a
    proportional share of the score rows, queries, and accumulator — the
    staging and codes terms are shared across the window."""
    m, d, group, ps = 1024, 128, 8, 64
    per_k = (m * 6                 # valid/kept bitmaps + f32 weight row
             + 3 * group * m * 4   # live score rows
             + 3 * group * d * 4   # whole + nibble-split queries
             + group * (d + 2) * 4)  # online-softmax accumulator
    b1 = fused_vmem_bytes(m, d, group, k=1, page_size=ps)
    for k in (2, 4, 8):
        assert fused_vmem_bytes(m, d, group, k=k,
                                page_size=ps) == b1 + (k - 1) * per_k


def test_fused_fits_budget_and_interpret():
    d, group = 128, 8
    # Interpret mode has no VMEM ceiling: the tri-state default fits.
    assert fused_fits(1 << 17, d, group)
    # The real budget check trips at large candidate capacity...
    assert fused_vmem_bytes(1 << 17, d, group) > FUSED_VMEM_BUDGET
    assert not fused_fits(1 << 17, d, group, interpret=False)
    assert fused_fits(1 << 10, d, group, interpret=False)
    # ...and a k=4 window trips it at a capacity where k=1 still fits.
    m = 1 << 15
    assert fused_fits(m, d, group, k=1, interpret=False)
    assert not fused_fits(m, d, group, k=4, interpret=False)


# ---------------------------------------------------------------------------
# Kernel vs oracle under adversarial survivor patterns
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pattern", ["alternating", "single_survivor",
                                     "all_kept", "empty_tail_page"])
def test_fused_op_adversarial_valid_patterns(rng, pattern):
    """Worst cases for run coalescing — run length 1 everywhere, a lone
    survivor, one maximal run per page, and a fully dropped tail page —
    must still match the oracle exactly."""
    q, K, V = _setup(rng, n=256)
    b, n, hkv, d = K.shape
    m = 96
    qkeys = quantize_int4(K)
    idx = jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32), (b, hkv, m))
    valid = jnp.broadcast_to(
        jnp.asarray(_kept_patterns(rng, m)[pattern]), (b, hkv, m))
    # p=1.0 keeps every valid slot: the DMA set IS the adversarial pattern.
    out, kept, w, th = fused_prune_attend(q, idx, valid, K, V, qkeys, p=1.0)
    ro, rk, rw, rt = fused_prune_attend_ref(q, idx, valid, K, V, qkeys,
                                            p=1.0)
    np.testing.assert_array_equal(np.asarray(kept), np.asarray(rk))
    np.testing.assert_array_equal(np.asarray(kept), np.asarray(valid))
    np.testing.assert_allclose(np.asarray(w), np.asarray(rw),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ro),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Multi-token window op
# ---------------------------------------------------------------------------

def _window_setup(rng, b=2, kw=3, hq=8, hkv=2, n=256, m=128, d=64):
    q = jnp.asarray(rng.normal(size=(b, kw, hq, d)), jnp.float32)
    K = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    qkeys = quantize_int4(K)
    idx = jnp.asarray(np.sort(rng.choice(n, size=(b, hkv, m)), -1), jnp.int32)
    base = jnp.asarray(rng.random((b, hkv, m)) < 0.9)
    # Window-causal validity: each position j adds a few more live slots,
    # mimicking "token L+j sees one more cache row than token L+j-1".
    grow = jnp.asarray(rng.random((b, kw, hkv, m)) < 0.05)
    valid = jnp.cumsum(grow, axis=1).astype(bool) | base[:, None]
    idx = jnp.where(valid.any(axis=1), idx, 0)
    return q, idx, valid, K, V, qkeys


def test_fused_window_op_matches_ref(rng):
    q, idx, valid, K, V, qkeys = _window_setup(rng)
    out, kept, w, th = fused_prune_attend_window(q, idx, valid, K, V, qkeys,
                                                 p=0.9)
    ro, rk, rw, rt = fused_prune_attend_window_ref(q, idx, valid, K, V,
                                                   qkeys, p=0.9)
    np.testing.assert_array_equal(np.asarray(kept), np.asarray(rk))
    np.testing.assert_allclose(np.asarray(w), np.asarray(rw),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(th), np.asarray(rt),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ro),
                               rtol=1e-4, atol=1e-5)


def test_fused_window_dead_position_emits_zeros(rng):
    """A window position whose validity row is all-False (slot queued fewer
    than kw tokens) keeps nothing and outputs exact zeros — junk from the
    shared DMA stream must not leak across positions."""
    q, idx, valid, K, V, qkeys = _window_setup(rng)
    valid = valid.at[0, -1].set(False)  # slot 0 only queued kw-1 tokens
    out, kept, w, th = fused_prune_attend_window(q, idx, valid, K, V, qkeys,
                                                 p=0.9)
    assert not np.asarray(kept)[0, -1].any()
    assert (np.asarray(w)[0, -1] == 0).all()
    np.testing.assert_array_equal(np.asarray(out)[0, -1], 0.0)
    # Live positions of the same slot are untouched by the dead one.
    ro, rk, _, _ = fused_prune_attend_window_ref(q, idx, valid, K, V, qkeys,
                                                 p=0.9)
    np.testing.assert_array_equal(np.asarray(kept), np.asarray(rk))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ro),
                               rtol=1e-4, atol=1e-5)


def test_fused_window_kw1_equals_single(rng):
    """kw = 1 window == the single-token op, bit for bit (same kernel,
    same grid, same accumulation order)."""
    q, K, V = _setup(rng, n=256)
    b, n, hkv, d = K.shape
    m = 128
    qkeys = quantize_int4(K)
    idx = jnp.asarray(np.sort(rng.choice(n, size=(b, hkv, m)), -1), jnp.int32)
    valid = jnp.asarray(rng.random((b, hkv, m)) < 0.9)
    idx = jnp.where(valid, idx, 0)
    single = fused_prune_attend(q, idx, valid, K, V, qkeys, p=0.9)
    window = fused_prune_attend_window(q[:, None], idx, valid[:, None],
                                       K, V, qkeys, p=0.9)
    for s, w in zip(single, window):
        np.testing.assert_array_equal(np.asarray(s), np.asarray(w[:, 0]))


# ---------------------------------------------------------------------------
# H2O page-mass accumulation through the window path
# ---------------------------------------------------------------------------

def test_h2o_mass_window_equals_sequential_updates(rng):
    """One window scatter-add == kw sequential single-step updates (the
    positions share a candidate buffer, so the scatter targets coincide
    and only the summation order differs)."""
    from repro.models.model import _h2o_mass_update, _h2o_mass_window_update

    b, kw, hkv, m, ps = 2, 3, 2, 64, 16
    num_pages, max_pages = 40, 8
    idx = jnp.asarray(rng.integers(0, max_pages * ps, (b, hkv, m)), jnp.int32)
    pt = jnp.asarray(rng.integers(1, num_pages, (b, max_pages)), jnp.int32)
    pv = jnp.asarray(rng.random((b, kw, hkv, m)) < 0.5)
    w = jnp.asarray(rng.random((b, kw, hkv, m)), jnp.float32)
    live = jnp.asarray([True, False])
    mass0 = jnp.asarray(rng.random((num_pages, hkv)), jnp.float32)

    win = SimpleNamespace(pruned_valid=pv, slot_weights=w, indices=idx)
    got = _h2o_mass_window_update(mass0, win, ps, pt, live)
    want = mass0
    for j in range(kw):
        step = SimpleNamespace(pruned_valid=pv[:, j], slot_weights=w[:, j],
                               indices=idx)
        want = _h2o_mass_update(want, step, ps, page_table=pt, live=live)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    # The dead slot contributed nothing: zero its weights and re-run.
    got_dead = _h2o_mass_window_update(
        mass0, SimpleNamespace(pruned_valid=pv.at[1].set(False),
                               slot_weights=w, indices=idx),
        ps, pt, live)
    np.testing.assert_allclose(np.asarray(got), np.asarray(got_dead),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Model level: window decode == k sequential steps (full selector)
# ---------------------------------------------------------------------------

def test_model_window_decode_matches_sequential(rng):
    """``decode_window_paged`` with kw teacher-forced tokens reproduces kw
    single ``decode_step_paged`` calls position for position — exact for
    the full selector (anchor-shared selection == per-step selection when
    every candidate is in the buffer), including ragged ``n_tok``."""
    from repro.models import (
        decode_step_paged,
        decode_window_paged,
        init_paged_decode_state,
        init_params,
        prefill,
        write_prefill_slot,
    )
    from repro.serving.paged_cache import PageAllocator, pages_for

    cfg = get_smoke_config("qwen2-1.5b")
    cfg = cfg.replace(twilight=dataclasses.replace(
        cfg.twilight, selector="full", candidate_frac=1.0,
        collect_run_stats=True))
    ps = cfg.twilight.page_size
    max_pages = 64 // ps
    params = init_params(cfg, jax.random.PRNGKey(7))
    prompts = [rng.integers(8, cfg.vocab_size, L).astype(np.int32)
               for L in (24, 13)]
    b, kw = 2, 3
    forced = np.stack([rng.integers(8, cfg.vocab_size, kw).astype(np.int32)
                       for _ in range(b)])

    def setup():
        alloc = PageAllocator(1 + b * max_pages)
        state = init_paged_decode_state(cfg, b, alloc.num_pages)
        pt = np.zeros((b, max_pages), np.int32)
        lengths = np.zeros((b,), np.int32)
        for s, pr in enumerate(prompts):
            n_req = pages_for(len(pr), ps)
            pages = alloc.alloc(n_req)
            _, pstate = prefill(params, cfg,
                                {"tokens": jnp.asarray(pr[None])},
                                n_max=n_req * ps)
            state = write_prefill_slot(cfg, state, pstate, s,
                                       jnp.asarray(pages))
            pt[s, :n_req] = pages
            lengths[s] = len(pr)
        return alloc, state, pt, lengths

    # Path A: kw sequential teacher-forced single steps.
    alloc, state, pt, lengths = setup()
    live = np.ones((b,), bool)
    seq = [[] for _ in range(b)]
    for i in range(kw):
        for s in range(b):
            if lengths[s] % ps == 0:
                pt[s, lengths[s] // ps] = alloc.alloc(1)[0]
        lg, state, stats = decode_step_paged(
            params, cfg, state, jnp.asarray(forced[:, i]), jnp.asarray(pt),
            jnp.asarray(lengths), jnp.asarray(live))
        for s in range(b):
            seq[s].append(np.asarray(lg[s, :cfg.vocab_size], np.float32))
        lengths += 1
    assert stats["run_stats"].shape == (runs_lib.RUN_STATS_LEN,)

    # Path B: one ragged window call (slot 1 only queues 2 of the kw).
    alloc, state, pt, lengths = setup()
    n_tok = np.asarray([kw, 2], np.int32)
    for s in range(b):
        for pos in range(lengths[s], lengths[s] + int(n_tok[s])):
            if pos % ps == 0:
                pt[s, pos // ps] = alloc.alloc(1)[0]
    lg, _, wstats = decode_window_paged(
        params, cfg, state, jnp.asarray(forced), jnp.asarray(pt),
        jnp.asarray(lengths), jnp.ones((b,), bool), jnp.asarray(n_tok))
    assert wstats["run_stats"].shape == (runs_lib.RUN_STATS_LEN,)
    for s in range(b):
        for j in range(int(n_tok[s])):
            np.testing.assert_allclose(
                np.asarray(lg[s, j, :cfg.vocab_size], np.float32),
                seq[s][j], rtol=2e-4, atol=2e-4,
                err_msg=f"slot {s} window pos {j}")
