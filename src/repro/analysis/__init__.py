from repro.analysis.costs import (
    decode_flops,
    decode_hbm_bytes,
    forward_flops,
    model_flops_6nd,
    param_count_estimate,
    train_hbm_bytes,
)

__all__ = [
    "decode_flops",
    "decode_hbm_bytes",
    "forward_flops",
    "model_flops_6nd",
    "param_count_estimate",
    "train_hbm_bytes",
]
