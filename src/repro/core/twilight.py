"""Twilight: the hierarchical Select-then-Prune pipeline (§4.1, Figure 5).

    q, KV cache ──► Token Selector (base algo, conservative B0)
                  ──► Twilight Pruner (INT4 estimate + top-p)
                  ──► Sparse Attention Kernel (pruned set only)

The pipeline is a pure function over arrays so it jits/shards/scans freely;
stateful concerns (paged cache, INT4 shadow cache maintenance, H2O stats)
live in ``repro.serving``.

Two representations of the candidate/pruned sets are supported:

* ``compact=True`` (default, the production path): the selector emits a
  **compact index buffer** (b, hkv, m) with m derived from the candidate
  budget B0; the pruner gathers INT4 codes at those indices and estimates
  scores on m-length rows; top-p binary-searches m-length rows; the final
  attention gathers K/V at the surviving slots.  Every stage after the
  selector is O(B0)/O(B1), never O(n) — the selector bounds *traffic*, the
  pruner bounds *compute* (§4.3).
* ``compact=False`` (the dense oracle / debug path): n-length boolean masks
  thread through every stage exactly as in the paper's definitions; used as
  the equivalence oracle in tests and for mask-level introspection.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import quant as quant_lib
from repro.core.attention import (
    compact_decode_attention,
    full_decode_attention,
    gather_kv_heads,
    masked_sparse_decode_attention,
)
from repro.core.pruner import PrunerStats, TwilightPruner
from repro.core.selectors import (
    SelectionContext,
    TokenSelector,
    physical_token_indices,
    selector_from_name,
)

__all__ = [
    "TwilightConfig",
    "TwilightOutput",
    "TwilightWindowOutput",
    "twilight_decode_attention",
    "twilight_decode_window_attention",
]


@dataclasses.dataclass(frozen=True)
class TwilightConfig:
    """Configuration of the full pipeline.

    ``candidate_frac`` is the conservative Token Selector sparsity (paper
    suggests 1/4); ``candidate_budget_cap`` bounds B0 absolutely so 500k+
    contexts stay tractable (pages-worth of tokens, see DESIGN §5).
    """

    enabled: bool = True
    selector: str = "quest"
    p: float = 0.95
    candidate_frac: float = 0.25
    candidate_budget_cap: int = 65536
    # Hierarchical page-level nucleus (the paper's *hierarchical* top-p):
    # when set (and < 1.0), page-granular selectors (quest/h2o) softmax
    # their per-page scores and keep only the top-``page_top_p`` nucleus of
    # candidate pages *before* the token-level top-p runs inside them.  The
    # candidate buffer keeps its static ``candidate_budget`` capacity — B0
    # becomes the *cap*, not the count — while the live candidate count
    # adapts per step, so the estimate stage only touches surviving pages
    # (the fused kernel early-outs whole dead pages; see
    # ``kernels/fused_decode``).  ``None`` or ``1.0`` is the flat fixed-B0
    # pipeline, bit for bit.  Token-granular selectors ignore it.
    page_top_p: float | None = None
    # Prefill-side hierarchical top-p (the TTFT counterpart of
    # ``page_top_p``): when set (and < 1.0), prefill attention — both the
    # dense contiguous path and the chunked paged walker — runs the
    # block-sparse flash kernel in ``kernels/sparse_prefill``: per query
    # block the Quest page min/max upper bound is max-reduced over the
    # block, passed through the same ``page_nucleus_mask`` search, and
    # only *surviving* pages are streamed and attended (causal-frontier
    # and recent pages are always kept, so every query row sees its own
    # page).  ``None`` or ``1.0`` is the dense prefill, bit for bit; the
    # kernel falls back to the dense path when the tile would overflow
    # VMEM (``sparse_prefill.ops.sparse_prefill_fits``).
    prefill_top_p: float | None = None
    page_size: int = 64
    estimate_bits: int = 4
    topp_iters: int = 24
    min_candidate: int = 64
    # prune_enabled=False degrades the pipeline to the *base algorithm
    # alone* (pure top-k: Quest/DS/... without the Twilight Pruner) — the
    # paper's baselines.  fixed_budget overrides candidate_frac with an
    # absolute token budget (the paper's budget-sweep rows).
    prune_enabled: bool = True
    fixed_budget: int = 0
    # Beyond-paper (suggested in §4.3 as future work): compute the *final*
    # attention against the INT4 shadow K instead of the fp16 K cache —
    # halves the final K read and, combined with offloading, removes the
    # need to keep fp16 K resident at all.  V stays full precision.
    reuse_int4_for_attention: bool = False
    # compact=True threads candidate *index buffers* through the pipeline
    # so estimate/top-p/attention cost scales with B0, not n; False keeps
    # the dense n-length masks (the oracle the compact path is tested
    # against).
    compact: bool = True
    # Optional second compaction before the final attention: the kept slots
    # are re-compacted (ranked by estimated weight, descending) into a
    # static buffer of ``pruned_cap_frac * m`` slots so the final K/V
    # gather reads ~B1 rows, not B0.  None attends over the full candidate
    # buffer behind the kept mask (exact).  With a cap, overflow beyond the
    # cap drops the *lowest-weight* kept slots — bounded mass loss; the
    # paper's measured B1 (~2% of n) sits far below the default serving cap
    # of 1/4 of the candidate buffer.
    pruned_cap_frac: float | None = None
    # Final-attention backend for the compact path: "jnp" is the reference,
    # "pallas" routes through the sparse_attn gathered kernel, "auto" picks
    # pallas only on a real TPU (interpret-mode Pallas is much slower than
    # jnp on CPU hosts).
    attn_backend: str = "auto"
    # Score-estimation backend for the compact path: "pallas" folds the INT4
    # dequantization into the spgemv kernel's matmul epilogue (d/2 bytes per
    # candidate row of HBM traffic); "jnp" gathers + dequantizes + einsums
    # (the reference and test oracle); "auto" picks pallas on a real TPU.
    estimate_backend: str = "auto"
    # Fully-fused decode backend: "fused" runs estimate → top-p → attend as
    # ONE Pallas launch (``kernels/fused_decode``) — scores, thresholds, and
    # index buffers never round-trip HBM, and only *surviving* K/V rows are
    # read; "staged" keeps the three-launch compact pipeline above; "auto"
    # fuses on a real TPU and stays staged elsewhere.  The staged pipeline
    # remains the equivalence oracle.  Fused silently falls back to staged
    # when there is nothing to fuse or the kernel cannot express the config:
    # pruning disabled, estimate_bits > 4 (the kernel consumes packed INT4
    # codes), reuse_int4_for_attention (final attention reads the fp cache),
    # or a candidate buffer beyond the kernel's VMEM budget
    # (``fused_decode.ops.fused_fits``).  ``pruned_cap_frac`` is moot on the
    # fused path: the kernel attends every kept slot (exact — equivalent to
    # the staged path with ``pruned_cap_frac=None``), since there is no
    # second K/V gather left to shrink.
    fused_backend: str = "auto"
    # Survivor-run telemetry: when True the paged decode step additionally
    # returns a fixed-size run-structure vector (histogram of contiguous
    # survivor run lengths, pages touched, kept rows — see
    # ``repro.core.runs``) accumulated over layers.  Off by default: the
    # stats cost a few O(B0) scans per layer and exist to make the fused
    # kernel's run-coalescing wins observable, not to steer it.
    collect_run_stats: bool = False

    def candidate_budget(self, n: int) -> int:
        """Static candidate capacity B0.  With ``page_top_p`` set this is
        the *cap* of the compact buffer; the live count adapts below it."""
        if self.fixed_budget:
            return min(self.fixed_budget, n)
        b0 = int(n * self.candidate_frac)
        b0 = max(self.min_candidate, min(b0, self.candidate_budget_cap))
        return min(b0, n)

    def make_selector(self, **kwargs) -> TokenSelector:
        if self.page_top_p is not None and self.selector in ("quest", "h2o"):
            kwargs.setdefault("page_top_p", self.page_top_p)
            kwargs.setdefault("nucleus_iters", self.topp_iters)
        return selector_from_name(self.selector, **kwargs)

    def make_pruner(self) -> TwilightPruner:
        return TwilightPruner(p=self.p, iters=self.topp_iters,
                              estimate_bits=self.estimate_bits,
                              use_spgemv=self.use_pallas_estimate())

    def pruned_capacity(self, m: int) -> int:
        """Static slot count of the post-top-p attention buffer."""
        if self.pruned_cap_frac is None:
            return m
        cap = max(1, int(m * self.pruned_cap_frac))
        return min(m, -(-cap // 128) * 128)  # lane-rounded

    def use_pallas_attention(self) -> bool:
        return self._resolve_backend(self.attn_backend, "attn_backend")

    def use_pallas_estimate(self) -> bool:
        # The spgemv kernel consumes packed INT4 codes; higher estimate
        # precisions stay on the jnp gather path.
        return (self.estimate_bits <= 4
                and self._resolve_backend(self.estimate_backend,
                                          "estimate_backend"))

    def use_fused_decode(self) -> bool:
        """Whether the compact pipeline should try the single-launch fused
        kernel.  The final static gate (candidate buffer vs VMEM budget)
        lives at the call site where the buffer capacity is known."""
        if not (self.enabled and self.compact and self.prune_enabled
                and self.estimate_bits <= 4
                and not self.reuse_int4_for_attention):
            return False
        return self._resolve_backend(self.fused_backend, "fused_backend",
                                     on="fused", off="staged")

    @staticmethod
    def _resolve_backend(value: str, what: str, *, on: str = "pallas",
                         off: str = "jnp") -> bool:
        if value == on:
            return True
        if value == off:
            return False
        if value != "auto":
            raise ValueError(f"unknown {what} {value!r}")
        return jax.default_backend() == "tpu"


class TwilightOutput(NamedTuple):
    """Pipeline output.

    The dense path fills the n-length ``candidate_mask``/``pruned_mask``;
    the compact path fills ``indices``/``candidate_valid``/``pruned_valid``
    (slot-granular over the index buffer) and leaves the masks None — use
    :func:`repro.core.selectors.indices_to_mask` to scatter them for
    debugging.
    """

    out: jax.Array  # (b, hq, d)
    candidate_mask: jax.Array | None  # (b, hkv, n) — dense path only
    pruned_mask: jax.Array | None  # (b, hkv, n) — dense path only
    stats: PrunerStats
    indices: jax.Array | None = None  # (b, hkv, m) i32 — compact path only
    candidate_valid: jax.Array | None = None  # (b, hkv, m) bool
    pruned_valid: jax.Array | None = None  # (b, hkv, m) bool
    # (b, hkv, m) f32 group-max post-softmax estimated weight per candidate
    # slot (compact path with pruning only).  The serving engine folds
    # ``slot_weights[pruned_valid]`` into its per-page H2O mass accumulator.
    slot_weights: jax.Array | None = None


def _trivial_stats(b: int, hq: int, hkv: int, n: jax.Array | int) -> PrunerStats:
    full = jnp.full((b, hkv), n, jnp.int32)
    return PrunerStats(candidate_budget=full, pruned_budget=full,
                       threshold=jnp.zeros((b, hq), jnp.float32), weights=None)


def _compact_pipeline(
    q: jax.Array,
    keys: jax.Array,
    values: jax.Array,
    cfg: TwilightConfig,
    selector: TokenSelector,
    b0: int,
    ctx: SelectionContext,
    qkeys: quant_lib.QuantizedTensor | None,
) -> TwilightOutput:
    b, hq = q.shape[0], q.shape[1]
    indices, valid = selector.select_indices(q, ctx, b0)  # (b, hkv, m) logical
    m = indices.shape[-1]

    # Paged cache: selectors emit logical positions; every downstream gather
    # (INT4 estimate, final K/V) addresses the shared pool through the
    # per-slot page table.  Dead slots resolve to the null page — safe to
    # gather, masked out by ``valid``.
    gather_idx = indices
    if ctx.page_table is not None:
        gather_idx = physical_token_indices(
            ctx.page_table, indices, ctx.page_meta.page_size)
        gather_idx = jnp.where(valid, gather_idx, 0)

    # Fused fast path: estimate → top-p → attend in ONE Pallas launch
    # (kernels/fused_decode).  Scores, thresholds, and index buffers stay in
    # VMEM; only surviving K/V rows are read from HBM.  The staged pipeline
    # below is the equivalence oracle (and the fallback for configs the
    # kernel cannot express — see ``TwilightConfig.fused_backend``).
    if cfg.prune_enabled and cfg.use_fused_decode():
        from repro.kernels.fused_decode.ops import fused_fits
        group = hq // indices.shape[1]
        if fused_fits(m, q.shape[-1], group, keys.dtype.itemsize,
                      page_size=cfg.page_size):
            out, kept, stats, slot_weights = cfg.make_pruner().prune_attend_at(
                q, gather_idx, valid, keys=keys, values=values, qkeys=qkeys,
                page_size=cfg.page_size,
                hierarchical=cfg.page_top_p is not None)
            return TwilightOutput(out=out, candidate_mask=None,
                                  pruned_mask=None, stats=stats,
                                  indices=indices, candidate_valid=valid,
                                  pruned_valid=kept,
                                  slot_weights=slot_weights)

    slot_weights = None
    if not cfg.prune_enabled:
        kept = valid
        stats = PrunerStats(
            candidate_budget=valid.sum(-1).astype(jnp.int32),
            pruned_budget=valid.sum(-1).astype(jnp.int32),
            threshold=jnp.zeros((b, hq), jnp.float32),
            weights=None,
        )
    else:
        pruner = cfg.make_pruner()
        kept, stats, slot_weights = pruner.prune_at(
            q, gather_idx, valid, keys=keys, qkeys=qkeys)

    # Final-attention buffer.  Default: every candidate slot is gathered
    # and pruned slots are masked out of the softmax (the Pallas kernel's
    # page early-out elides their compute).  With pruned_cap_frac the kept
    # slots are re-compacted (weight-ranked) so the K/V gather reads ~B1
    # rows instead of B0.
    attn_indices, attn_valid = gather_idx, kept
    b1_cap = cfg.pruned_capacity(m)
    if slot_weights is not None and b1_cap < m:
        rank = jnp.where(kept, slot_weights, -1.0)
        _, slot_idx = jax.lax.top_k(rank, b1_cap)  # (b, hkv, b1_cap)
        attn_valid = jnp.take_along_axis(kept, slot_idx, axis=-1)
        attn_indices = jnp.where(
            attn_valid, jnp.take_along_axis(gather_idx, slot_idx, axis=-1), 0)

    if cfg.reuse_int4_for_attention and qkeys is not None:
        gathered_q = quant_lib.QuantizedTensor(
            packed=gather_kv_heads(qkeys.packed, attn_indices),
            scale=gather_kv_heads(qkeys.scale, attn_indices),
            zero=gather_kv_heads(qkeys.zero, attn_indices))
        kg = quant_lib.dequantize_int4(gathered_q, dtype=keys.dtype)
    else:
        kg = gather_kv_heads(keys, attn_indices)
    vg = gather_kv_heads(values, attn_indices)
    if cfg.use_pallas_attention():
        from repro.kernels.sparse_attn.ops import compact_attention
        out = compact_attention(q, kg, vg, attn_valid)
    else:
        out = compact_decode_attention(q, kg, vg, attn_valid)
    return TwilightOutput(out=out, candidate_mask=None, pruned_mask=None,
                          stats=stats, indices=indices, candidate_valid=valid,
                          pruned_valid=kept, slot_weights=slot_weights)


def twilight_decode_attention(
    q: jax.Array,  # (b, hq, d)
    keys: jax.Array,  # (b, n, hkv, d)
    values: jax.Array,  # (b, n, hkv, d)
    cfg: TwilightConfig,
    *,
    ctx: SelectionContext | None = None,
    qkeys: quant_lib.QuantizedTensor | None = None,
    length: jax.Array | None = None,
) -> TwilightOutput:
    """One decode-step of Twilight-optimized sparse attention.

    When ``cfg.enabled`` is False this degrades to exact full attention with
    trivial masks/stats — the "Full" baseline rows of Tables 2–4.

    Paged mode (``ctx.page_table`` set): ``keys``/``values`` are the shared
    (num_pages * page_size, hkv, d) pool and only the compact pipeline is
    supported — the dense-mask oracle keeps the contiguous layout.
    """
    paged = ctx is not None and ctx.page_table is not None
    if paged:
        if not (cfg.enabled and cfg.compact):
            raise ValueError(
                "paged KV caches require the compact Twilight pipeline "
                "(cfg.enabled=True, cfg.compact=True)")
        n = ctx.page_table.shape[1] * ctx.page_meta.page_size
        hkv = keys.shape[-2]
    else:
        _, n, hkv, _ = keys.shape
    b = q.shape[0]
    hq = q.shape[1]

    if not cfg.enabled:
        out = full_decode_attention(q, keys, values, length=length)
        ones = jnp.ones((b, hkv, n), bool)
        return TwilightOutput(out=out, candidate_mask=ones, pruned_mask=ones,
                              stats=_trivial_stats(b, hq, hkv, n))

    if ctx is None:
        # Ergonomic fallback: derive selector metadata from the keys.  The
        # serving engine maintains these incrementally instead.
        from repro.core.selectors import build_page_meta, calibrate_ds_channels
        pm = (build_page_meta(keys, cfg.page_size)
              if n % cfg.page_size == 0 else None)
        ds = (calibrate_ds_channels(keys, 16)
              if cfg.selector in ("ds", "double_sparsity") else None)
        ctx = SelectionContext(keys=keys, page_meta=pm, accum_scores=None,
                               length=length, ds_channels=ds)

    selector = cfg.make_selector()
    b0 = cfg.candidate_budget(n)

    if cfg.compact:
        return _compact_pipeline(q, keys, values, cfg, selector, b0, ctx,
                                 qkeys)

    candidate_mask = selector.select(q, ctx, b0)  # (b, hkv, n)
    if not cfg.prune_enabled:
        # Base algorithm alone (pure top-k baseline rows of Tables 2-4).
        pruned_mask = candidate_mask
        stats = PrunerStats(
            candidate_budget=candidate_mask.sum(-1).astype(jnp.int32),
            pruned_budget=candidate_mask.sum(-1).astype(jnp.int32),
            threshold=jnp.zeros((b, hq), jnp.float32),
            weights=None,
        )
    else:
        pruner = cfg.make_pruner()
        pruned_mask, stats = pruner.prune(q, candidate_mask, keys=keys,
                                          qkeys=qkeys)

    attn_keys = keys
    if cfg.reuse_int4_for_attention and qkeys is not None:
        attn_keys = quant_lib.dequantize_int4(qkeys, dtype=keys.dtype)
    out = masked_sparse_decode_attention(q, attn_keys, values, pruned_mask)
    return TwilightOutput(out=out, candidate_mask=candidate_mask,
                          pruned_mask=pruned_mask, stats=stats)


class TwilightWindowOutput(NamedTuple):
    """Output of one multi-token window decode (kw queued positions).

    Selection is anchored once at the last live position (``n_tok - 1``);
    every per-position array carries a leading kw axis.  ``stats`` reports
    the anchor position (what a single-token step at that position would
    report).  Positions >= n_tok are dead: their validity/kept masks are
    all-False and their outputs are zeros.
    """

    out: jax.Array  # (b, kw, hq, d)
    stats: PrunerStats  # anchor position (n_tok - 1)
    indices: jax.Array  # (b, hkv, m) i32 — shared candidate buffer
    candidate_valid: jax.Array  # (b, kw, hkv, m) — causal per-position
    pruned_valid: jax.Array  # (b, kw, hkv, m)
    slot_weights: jax.Array | None  # (b, kw, hkv, m)


def twilight_decode_window_attention(
    q: jax.Array,  # (b, kw, hq, d) — kw queued window positions per slot
    keys: jax.Array,
    values: jax.Array,
    cfg: TwilightConfig,
    *,
    ctx: SelectionContext,
    qkeys: quant_lib.QuantizedTensor | None = None,
    lengths: jax.Array,  # (b,) i32 — window start (tokens already cached)
    n_tok: jax.Array,  # (b,) i32 in [1, kw] — live positions this window
) -> TwilightWindowOutput:
    """Multi-token decode: kw queued positions against ONE candidate set.

    The Token Selector runs once per window, anchored at the last live
    position (Tactic: survivor sets are temporally stable across adjacent
    decode positions, so the anchor's candidates cover the whole window);
    each position then prunes and attends its own causal restriction of
    that buffer (position j sees logical indices <= lengths + j).  On the
    fused backend this is ONE kernel launch per layer for all kw positions
    — the window union of survivor sets is streamed from HBM once.

    Anchored selection is exact (identical to kw single-token steps) for
    the "full" selector and for windows with n_tok = 1; query-dependent
    selectors (quest/ds/streaming/h2o) may select slightly different
    candidates than a per-position step would — the serving engine
    therefore makes window decode opt-in.

    ``ctx.length`` must already be the *post-window* length
    (lengths + n_tok), matching the single-token convention where
    ``length`` includes the position being decoded.
    """
    b, kw, hq, d = q.shape
    if kw == 1:
        single = twilight_decode_attention(
            q[:, 0], keys, values, cfg, ctx=ctx, qkeys=qkeys,
            length=ctx.length)
        sw = single.slot_weights
        return TwilightWindowOutput(
            out=single.out[:, None], stats=single.stats,
            indices=single.indices,
            candidate_valid=single.candidate_valid[:, None],
            pruned_valid=single.pruned_valid[:, None],
            slot_weights=None if sw is None else sw[:, None])

    if not (cfg.enabled and cfg.compact):
        raise ValueError(
            "window decode requires the compact Twilight pipeline "
            "(cfg.enabled=True, cfg.compact=True)")
    paged = ctx.page_table is not None
    n = (ctx.page_table.shape[1] * ctx.page_meta.page_size if paged
         else keys.shape[1])
    hkv = keys.shape[-2]
    group = hq // hkv
    selector = cfg.make_selector()
    b0 = cfg.candidate_budget(n)

    anchor = (n_tok - 1).astype(jnp.int32)
    q_anchor = jnp.take_along_axis(
        q, anchor[:, None, None, None], axis=1)[:, 0]
    indices, valid = selector.select_indices(q_anchor, ctx, b0)
    m = indices.shape[-1]

    # Causal window restriction: position j may attend logical indices
    # <= lengths + j (its own row included); dead positions see nothing,
    # so they contribute neither survivors nor DMA traffic.
    win_pos = lengths[:, None] + jnp.arange(kw)[None, :]  # (b, kw)
    live_pos = (jnp.arange(kw)[None, :] < n_tok[:, None])  # (b, kw)
    valid_k = (valid[:, None]
               & (indices[:, None] <= win_pos[:, :, None, None])
               & live_pos[:, :, None, None])  # (b, kw, hkv, m)

    gather_idx = indices
    if paged:
        gather_idx = physical_token_indices(
            ctx.page_table, indices, ctx.page_meta.page_size)
        gather_idx = jnp.where(valid, gather_idx, 0)

    def anchor_row(x):  # (b, kw, ...) -> (b, ...) at the anchor position
        idx = anchor.reshape((b,) + (1,) * (x.ndim - 1))
        return jnp.take_along_axis(x, idx, axis=1)[:, 0]

    if cfg.prune_enabled and cfg.use_fused_decode():
        from repro.kernels.fused_decode.ops import fused_fits
        if fused_fits(m, d, group, keys.dtype.itemsize, k=kw,
                      page_size=cfg.page_size):
            out, kept, slot_w, thresh = (
                cfg.make_pruner().prune_attend_window_at(
                    q, gather_idx, valid_k, keys=keys, values=values,
                    qkeys=qkeys, page_size=cfg.page_size,
                    hierarchical=cfg.page_top_p is not None))
            stats = PrunerStats(
                candidate_budget=anchor_row(
                    valid_k.sum(-1)).astype(jnp.int32),
                pruned_budget=anchor_row(kept.sum(-1)).astype(jnp.int32),
                threshold=anchor_row(thresh),
                weights=None)
            return TwilightWindowOutput(
                out=out, stats=stats, indices=indices,
                candidate_valid=valid_k, pruned_valid=kept,
                slot_weights=slot_w)

    # Staged window fallback: one folded estimate, then per-position top-p
    # and (optionally capped) attend — position j's slice is exactly the
    # single-token staged pipeline at that position.
    slot_w = None
    if not cfg.prune_enabled:
        kept = valid_k
        thresh = jnp.zeros((b, kw, hq), jnp.float32)
    else:
        kept, thresh, slot_w = cfg.make_pruner().prune_window_at(
            q, gather_idx, valid_k, keys=keys, qkeys=qkeys)

    b1_cap = cfg.pruned_capacity(m)
    outs = []
    for j in range(kw):
        attn_indices, attn_valid = gather_idx, kept[:, j]
        if slot_w is not None and b1_cap < m:
            rank = jnp.where(kept[:, j], slot_w[:, j], -1.0)
            _, slot_idx = jax.lax.top_k(rank, b1_cap)
            attn_valid = jnp.take_along_axis(kept[:, j], slot_idx, axis=-1)
            attn_indices = jnp.where(
                attn_valid,
                jnp.take_along_axis(gather_idx, slot_idx, axis=-1), 0)
        if cfg.reuse_int4_for_attention and qkeys is not None:
            gathered_q = quant_lib.QuantizedTensor(
                packed=gather_kv_heads(qkeys.packed, attn_indices),
                scale=gather_kv_heads(qkeys.scale, attn_indices),
                zero=gather_kv_heads(qkeys.zero, attn_indices))
            kg = quant_lib.dequantize_int4(gathered_q, dtype=keys.dtype)
        else:
            kg = gather_kv_heads(keys, attn_indices)
        vg = gather_kv_heads(values, attn_indices)
        if cfg.use_pallas_attention():
            from repro.kernels.sparse_attn.ops import compact_attention
            outs.append(compact_attention(q[:, j], kg, vg, attn_valid))
        else:
            outs.append(compact_decode_attention(q[:, j], kg, vg, attn_valid))
    out = jnp.stack(outs, axis=1)
    stats = PrunerStats(
        candidate_budget=anchor_row(valid_k.sum(-1)).astype(jnp.int32),
        pruned_budget=anchor_row(kept.sum(-1)).astype(jnp.int32),
        threshold=anchor_row(thresh),
        weights=None)
    return TwilightWindowOutput(out=out, stats=stats, indices=indices,
                                candidate_valid=valid_k, pruned_valid=kept,
                                slot_weights=slot_w)
