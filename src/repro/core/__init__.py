"""Twilight core: adaptive attention sparsity with hierarchical top-p pruning.

Public API of the paper's contribution.  Everything here is a pure function
over jax arrays (jit/shard/scan-safe); stateful cache plumbing lives in
``repro.serving``.
"""

from repro.core.attention import (
    attention_error,
    compact_decode_attention,
    full_decode_attention,
    gather_kv_heads,
    gathered_sparse_decode_attention,
    masked_sparse_decode_attention,
    mha_attention,
)
from repro.core.pruner import PrunerStats, TwilightPruner
from repro.core.quant import QuantizedTensor, dequantize_int4, quantize_int4
from repro.core.selectors import (
    DoubleSparsitySelector,
    FullSelector,
    H2OSelector,
    PageMeta,
    QuestSelector,
    SelectionContext,
    StreamingSelector,
    TokenSelector,
    build_page_meta,
    calibrate_ds_channels,
    gather_logical_rows,
    group_union,
    index_capacity,
    indices_from_mask,
    indices_to_mask,
    physical_token_indices,
    selector_from_name,
    topk_mask,
)
from repro.core.topp import (
    ToppResult,
    masked_softmax,
    oracle_topp_mask,
    topp_mask,
    topp_threshold,
)
from repro.core.twilight import (
    TwilightConfig,
    TwilightOutput,
    TwilightWindowOutput,
    twilight_decode_attention,
    twilight_decode_window_attention,
)

__all__ = [
    "attention_error",
    "compact_decode_attention",
    "full_decode_attention",
    "gather_kv_heads",
    "gathered_sparse_decode_attention",
    "masked_sparse_decode_attention",
    "mha_attention",
    "PrunerStats",
    "TwilightPruner",
    "QuantizedTensor",
    "dequantize_int4",
    "quantize_int4",
    "DoubleSparsitySelector",
    "FullSelector",
    "H2OSelector",
    "PageMeta",
    "QuestSelector",
    "SelectionContext",
    "StreamingSelector",
    "TokenSelector",
    "build_page_meta",
    "calibrate_ds_channels",
    "gather_logical_rows",
    "group_union",
    "index_capacity",
    "indices_from_mask",
    "indices_to_mask",
    "physical_token_indices",
    "selector_from_name",
    "topk_mask",
    "ToppResult",
    "masked_softmax",
    "oracle_topp_mask",
    "topp_mask",
    "topp_threshold",
    "TwilightConfig",
    "TwilightOutput",
    "TwilightWindowOutput",
    "twilight_decode_attention",
    "twilight_decode_window_attention",
]
