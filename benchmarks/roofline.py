"""§Roofline: three-term analysis per (arch × shape) from the dry-run.

    compute term    = FLOPs / (chips × 197 TFLOP/s)
    memory term     = HBM bytes / (chips × 819 GB/s)
    collective term = collective bytes / (chips × 50 GB/s)

FLOPs and HBM bytes are analytic (``repro.analysis.costs``) because XLA's
``cost_analysis`` counts scan/while bodies once (layer stacks, grad-accum
and time scans would be undercounted by their trip counts); the HLO numbers
from the dry-run JSONL are retained as per-iteration cross-checks.
Collective bytes come from the optimized-HLO parse, scaled by the known
loop trip factors (layer-scan repeats × grad-accum microsteps).

Usage: PYTHONPATH=src python -m benchmarks.roofline [results/dryrun_baseline.jsonl]

``--fused`` instead prints the fused-vs-staged decode-pipeline table: per
step per attention layer, the modeled HBM bytes and Pallas launch count of
the staged compact pipeline (spgemv estimate → top-p → gathered attention,
inter-stage buffers round-tripping HBM) against the single-launch fused
kernel (``kernels/fused_decode``), at the serving config
(``candidate_frac=0.25``, ``pruned_cap_frac=0.25``).
"""

from __future__ import annotations

import json
import os

from repro.analysis.costs import (
    active_param_count,
    collective_bytes_per_chip,
    decode_flops,
    decode_hbm_bytes,
    forward_flops,
    model_flops_6nd,
    param_count_estimate,
    prefill_hbm_bytes,
    train_hbm_bytes,
    train_step_flops,
    twilight_pipeline_traffic,
)
from repro.configs import get_config, list_archs
from repro.launch.specs import INPUT_SHAPES
from repro.models.model import layer_schedule

CHIPS = 256
PEAK = 197e12
HBM = 819e9
ICI = 50e9

DEFAULT_JSONL = os.path.join(os.path.dirname(__file__), "..", "results",
                             "dryrun_baseline.jsonl")


def _loop_factor(cfg, shape) -> float:
    """Collectives live inside the layer scan (and grad-accum scan)."""
    _, repeats = layer_schedule(cfg)
    accum = 1
    if shape.kind == "train":
        n = param_count_estimate(cfg)
        accum = 8 if n > 100e9 else (2 if n > 20e9 else 1)
    return repeats * accum


def analyze_cell(arch: str, shape_name: str, hlo_row: dict | None) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    tokens = b * s

    if shape.kind == "train":
        flops = train_step_flops(cfg, b, s)
        hbm = train_hbm_bytes(cfg, b, s)
        mf = model_flops_6nd(cfg, tokens, train=True)
    elif shape.kind == "prefill":
        flops = forward_flops(cfg, b, s)
        hbm = prefill_hbm_bytes(cfg, b, s)
        mf = model_flops_6nd(cfg, tokens, train=False)
    else:
        flops = decode_flops(cfg, b, s)
        hbm = decode_hbm_bytes(cfg, b, s)
        mf = model_flops_6nd(cfg, b, train=False)

    compute_s = flops / (CHIPS * PEAK)
    memory_s = hbm / (CHIPS * HBM)

    accum = 1
    if shape.kind == "train":
        n = param_count_estimate(cfg)
        accum = 8 if n > 100e9 else (2 if n > 20e9 else 1)
    coll = collective_bytes_per_chip(cfg, shape.kind, b, s, grad_accum=accum)
    collective_s = coll["total"] / ICI  # per-chip bytes over per-chip links

    hlo_coll_gib = None
    if hlo_row and "collective_bytes" in hlo_row:
        # Per-iteration lower bound (XLA counts loop bodies once).
        hlo_coll_gib = sum(hlo_row["collective_bytes"].values()) / 2**30

    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    levers = {
        "compute": "raise MXU utilization (larger fused matmul tiles / "
                   "lower-precision matmuls) or shard more ways",
        "memory": "cut HBM traffic: deeper Twilight pruning (smaller B1), "
                  "INT4-for-final-attention, fused dequant",
        "collective": "reshard to remove all-gathers (keep contracting dims "
                      "local) or overlap collectives with compute",
    }
    return {
        "arch": arch,
        "shape": shape_name,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops": mf,
        "analytic_flops": flops,
        "useful_ratio": mf / flops,
        "hlo_flops_per_chip": (hlo_row or {}).get("flops"),
        "hlo_coll_gib_per_iter": hlo_coll_gib,
        "coll_breakdown": coll,
        "temp_gib": ((hlo_row or {}).get("memory", {}).get("temp_bytes") or 0)
        / 2**30,
        "lever": levers[dominant],
        "params_b": param_count_estimate(cfg) / 1e9,
        "active_b": active_param_count(cfg) / 1e9,
    }


def load_hlo_rows(path: str) -> dict:
    rows = {}
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                r = json.loads(line)
                if r.get("mesh") == "16x16" and "error" not in r:
                    rows[(r["arch"], r["shape"])] = r
    return rows


def full_table(jsonl_path: str = DEFAULT_JSONL) -> list[dict]:
    hlo = load_hlo_rows(jsonl_path)
    out = []
    for arch in list_archs():
        for shape in INPUT_SHAPES:
            out.append(analyze_cell(arch, shape, hlo.get((arch, shape))))
    return out


def print_table(rows: list[dict]) -> None:
    hdr = (f"{'arch':24s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'coll_s':>10s} {'dominant':>10s} {'useful':>7s} {'temp GiB':>9s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['arch']:24s} {r['shape']:12s} {r['compute_s']:10.3e} "
              f"{r['memory_s']:10.3e} {r['collective_s']:10.3e} "
              f"{r['dominant']:>10s} {r['useful_ratio']:7.2f} "
              f"{r['temp_gib']:9.2f}")


def fused_table(contexts=(8192, 32768, 65536, 131072), *, hq=32, hkv=8,
                d=128) -> list[dict]:
    """Fused-vs-staged decode traffic per step per attention layer.

    LLaMA-class GQA shape, serving Twilight config.  ``bytes_x`` /
    ``launches_x`` are the staged/fused reduction factors the fused kernel
    buys; ``tail_x`` excludes the (identical) selector page scan.
    ``row_eff`` / ``run_eff`` price the fused kernel's survivor DMA at
    per-row vs run-coalesced transaction granularity (payload + per-copy
    overhead — the *effective* bytes a bandwidth model sees); ``dma_x`` is
    the effective-bandwidth improvement run coalescing buys.
    """
    from repro.analysis.costs import serving_pipeline_config

    tw = serving_pipeline_config()
    rows = []
    for n in contexts:
        st = twilight_pipeline_traffic(tw, n, hq, hkv, d, fused=False)
        fu = twilight_pipeline_traffic(tw, n, hq, hkv, d, fused=True)
        row = twilight_pipeline_traffic(tw, n, hq, hkv, d, fused=True,
                                        dma="row")
        run = twilight_pipeline_traffic(tw, n, hq, hkv, d, fused=True,
                                        dma="run")
        rows.append({
            "n": n,
            "staged_bytes": st["total"], "fused_bytes": fu["total"],
            "staged_tail": st["tail"], "fused_tail": fu["tail"],
            "staged_launches": st["launches"],
            "fused_launches": fu["launches"],
            "bytes_x": st["total"] / fu["total"],
            "tail_x": st["tail"] / fu["tail"],
            "launches_x": st["launches"] / fu["launches"],
            "row_eff": row["total_eff"], "run_eff": run["total_eff"],
            "row_txns": row["attend_txns"], "run_txns": run["attend_txns"],
            "dma_x": row["total_eff"] / run["total_eff"],
        })
    return rows


def print_fused_table(rows: list[dict]) -> None:
    hdr = (f"{'context':>9s} {'staged MB':>10s} {'fused MB':>9s} "
           f"{'bytes_x':>8s} {'tail_x':>7s} {'launches':>9s} "
           f"{'rowDMA MB':>10s} {'runDMA MB':>10s} {'dma_x':>6s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['n']:9d} {r['staged_bytes'] / 1e6:10.2f} "
              f"{r['fused_bytes'] / 1e6:9.2f} {r['bytes_x']:8.2f} "
              f"{r['tail_x']:7.2f} "
              f"{r['staged_launches']:.0f} -> {r['fused_launches']:.0f}    "
              f"{r['row_eff'] / 1e6:10.2f} {r['run_eff'] / 1e6:10.2f} "
              f"{r['dma_x']:6.2f}")


def multitok_table(contexts=(8192, 32768, 65536, 131072), ks=(1, 2, 4, 8),
                   *, hq=32, hkv=8, d=128) -> list[dict]:
    """Multi-token fused decode: per-token effective bytes and launches.

    One fused launch decodes ``k`` queued tokens (preemption replay,
    speculative verify) against the union of their survivor sets — K/V
    runs stream once for all ``k`` online-softmax accumulators.
    ``per_tok_x``/``launch_x`` are the k=1 / k improvement factors.
    """
    from repro.analysis.costs import serving_pipeline_config

    tw = serving_pipeline_config()
    rows = []
    for n in contexts:
        base = twilight_pipeline_traffic(tw, n, hq, hkv, d, fused=True,
                                         dma="run", k=1)
        for k in ks:
            r = twilight_pipeline_traffic(tw, n, hq, hkv, d, fused=True,
                                          dma="run", k=k)
            rows.append({
                "n": n, "k": k,
                "total_eff": r["total_eff"],
                "per_token": r["per_token"],
                "launches_per_token": r["launches_per_token"],
                "per_tok_x": base["per_token"] / r["per_token"],
                "launch_x": (base["launches_per_token"]
                             / r["launches_per_token"]),
            })
    return rows


def print_multitok_table(rows: list[dict]) -> None:
    hdr = (f"{'context':>9s} {'k':>3s} {'eff MB':>8s} {'per-tok MB':>11s} "
           f"{'launch/tok':>11s} {'per_tok_x':>10s} {'launch_x':>9s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['n']:9d} {r['k']:3d} {r['total_eff'] / 1e6:8.2f} "
              f"{r['per_token'] / 1e6:11.3f} {r['launches_per_token']:11.3f} "
              f"{r['per_tok_x']:10.2f} {r['launch_x']:9.2f}")


def hierarchical_table(contexts=(8192, 32768, 65536, 131072),
                       ps=(0.8, 0.9, 0.95), *, hq=32, hkv=8,
                       d=128) -> list[dict]:
    """Hierarchical page→token top-p: adaptive-estimate traffic vs flat.

    For each (context, ``page_top_p``) cell, price the fused pipeline with
    the page nucleus on vs off.  ``est_x`` is the estimate-stage bytes
    reduction the page-level early-out buys (dead pages' INT4 codes are
    never scored); ``total_x``/``eff_x`` are the end-to-end payload /
    effective (run-DMA) improvements, net of the extra ``page_topp``
    scoring term.
    """
    import dataclasses

    from repro.analysis.costs import (
        hierarchical_page_survivors,
        serving_pipeline_config,
    )

    tw = serving_pipeline_config()
    rows = []
    for n in contexts:
        flat = twilight_pipeline_traffic(tw, n, hq, hkv, d, fused=True,
                                         dma="run")
        for p in ps:
            twh = dataclasses.replace(tw, page_top_p=p)
            hier = twilight_pipeline_traffic(twh, n, hq, hkv, d, fused=True,
                                             dma="run")
            n_pages = tw.candidate_budget(n) // tw.page_size
            rows.append({
                "n": n, "page_top_p": p,
                "cand_pages": n_pages,
                "live_pages": hierarchical_page_survivors(n_pages, p),
                "flat_estimate": flat["estimate"],
                "hier_estimate": hier["estimate"],
                "page_topp_bytes": hier["page_topp"],
                "est_x": flat["estimate"] / hier["estimate"],
                "total_x": flat["total"] / hier["total"],
                "eff_x": flat["total_eff"] / hier["total_eff"],
            })
    return rows


def print_hierarchical_table(rows: list[dict]) -> None:
    hdr = (f"{'context':>9s} {'p_page':>7s} {'pages':>11s} "
           f"{'flat est MB':>12s} {'hier est MB':>12s} {'est_x':>6s} "
           f"{'total_x':>8s} {'eff_x':>6s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['n']:9d} {r['page_top_p']:7.2f} "
              f"{r['live_pages']:5d}/{r['cand_pages']:<5d} "
              f"{r['flat_estimate'] / 1e6:12.3f} "
              f"{r['hier_estimate'] / 1e6:12.3f} {r['est_x']:6.2f} "
              f"{r['total_x']:8.2f} {r['eff_x']:6.2f}")


def prefill_table(contexts=(8192, 32768, 65536, 131072),
                  ps=(0.8, 0.9, 0.95), *, hq=32, hkv=8, d=128) -> list[dict]:
    """Hierarchical top-p sparse prefill: TTFT-path attention bytes.

    For each (context, ``prefill_top_p``) cell, the modeled per-layer
    K/V HBM bytes of a from-scratch prefill: the dense flash oracle
    (every query tile streams its whole causal context) vs the
    page-nucleus sparse kernel (``kernels/sparse_prefill`` — survivor
    pages only, plus the page-metadata read and per-tile page-score
    rows).  ``bytes_x`` is the end-to-end prefill traffic reduction.
    """
    import dataclasses

    from repro.analysis.costs import (
        prefill_attention_traffic,
        serving_pipeline_config,
    )

    tw = serving_pipeline_config()
    rows = []
    for n in contexts:
        dense = prefill_attention_traffic(tw, n, hq, hkv, d)
        for p in ps:
            twp = dataclasses.replace(tw, prefill_top_p=p)
            sp = prefill_attention_traffic(twp, n, hq, hkv, d)
            rows.append({
                "n": n, "prefill_top_p": p,
                "dense_bytes": dense["total"],
                "sparse_bytes": sp["total"],
                "attend_bytes": sp["attend"],
                "meta_bytes": sp["meta"],
                "page_topp_bytes": sp["page_topp"],
                "bytes_x": sp["bytes_x"],
            })
    return rows


def print_prefill_table(rows: list[dict]) -> None:
    hdr = (f"{'context':>9s} {'p_prefill':>10s} {'dense MB':>10s} "
           f"{'sparse MB':>10s} {'attend MB':>10s} {'meta MB':>8s} "
           f"{'bytes_x':>8s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['n']:9d} {r['prefill_top_p']:10.2f} "
              f"{r['dense_bytes'] / 1e6:10.1f} "
              f"{r['sparse_bytes'] / 1e6:10.1f} "
              f"{r['attend_bytes'] / 1e6:10.1f} "
              f"{r['meta_bytes'] / 1e6:8.2f} {r['bytes_x']:8.2f}")


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("jsonl", nargs="?", default=DEFAULT_JSONL,
                    help="dry-run HLO JSONL for per-iteration cross-checks")
    ap.add_argument("--fused", action="store_true",
                    help="print the fused-vs-staged decode-pipeline bytes/"
                         "launch table instead of the arch roofline")
    ap.add_argument("--multitok", action="store_true",
                    help="also print the multi-token fused decode table "
                         "(per-token effective bytes and launches vs k)")
    ap.add_argument("--hierarchical", action="store_true",
                    help="also print the hierarchical page-nucleus table "
                         "(adaptive-estimate bytes vs the flat pipeline)")
    ap.add_argument("--prefill", action="store_true",
                    help="also print the sparse-prefill TTFT table "
                         "(page-nucleus prefill bytes vs dense flash)")
    args = ap.parse_args()
    if args.fused or args.multitok or args.hierarchical or args.prefill:
        outdir = os.path.dirname(args.jsonl) or "."
        os.makedirs(outdir, exist_ok=True)
        first = True
        if args.fused:
            rows = fused_table()
            print_fused_table(rows)
            out = os.path.join(outdir, "roofline_fused.json")
            with open(out, "w") as f:
                json.dump(rows, f, indent=1)
            print(f"\nwrote {out}")
            first = False
        if args.multitok:
            if not first:
                print()
            mrows = multitok_table()
            print_multitok_table(mrows)
            mout = os.path.join(outdir, "roofline_multitok.json")
            with open(mout, "w") as f:
                json.dump(mrows, f, indent=1)
            print(f"\nwrote {mout}")
            first = False
        if args.hierarchical:
            if not first:
                print()
            hrows = hierarchical_table()
            print_hierarchical_table(hrows)
            hout = os.path.join(outdir, "roofline_hier.json")
            with open(hout, "w") as f:
                json.dump(hrows, f, indent=1)
            print(f"\nwrote {hout}")
            first = False
        if args.prefill:
            if not first:
                print()
            prows = prefill_table()
            print_prefill_table(prows)
            pout = os.path.join(outdir, "roofline_prefill.json")
            with open(pout, "w") as f:
                json.dump(prows, f, indent=1)
            print(f"\nwrote {pout}")
        return
    path = args.jsonl
    rows = full_table(path)
    print_table(rows)
    out = os.path.join(os.path.dirname(path) or ".", "roofline.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\nwrote {out}")


if __name__ == "__main__":
    main()
