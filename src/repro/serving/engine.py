"""Serving engine: wave-batched (contiguous) and persistent continuous decode.

Three scheduling modes around the same model:

* ``paged=False`` — the legacy wave scheduler: fixed batch slots, every
  request in a wave decodes for the wave's ``max(max_new_tokens)`` against a
  per-slot contiguous cache of ``cache_capacity`` tokens.  Kept as the
  equivalence oracle (same role as ``TwilightConfig.compact=False``).
  Waves are formed so that each request keeps ``cache_capacity -
  max_new_tokens`` of its *own* prompt — a long-prompt/short-generation
  request is no longer truncated by a wave mate's generation budget.
* ``paged=True`` — **persistent continuous batching** over a shared page
  pool (``repro.serving.paged_cache``): slots retire and admit new requests
  at every decode step; each request owns only the KV pages its tokens fill.
  Per-request ``max_new_tokens``, ragged prompt lengths, and per-slot
  sampling modes are all data; the jitted step is compiled once per
  (batch, num_pages, max_pages) and reused.
* ``paged=True, prefix_share=True`` — continuous batching plus **prefix
  sharing with copy-on-write pages and chunked prefill** (attention-only
  stacks, :func:`repro.models.supports_chunked_prefill`).  On admission the
  engine matches the longest page-aligned cached prefix in a radix tree
  (``repro.serving.prefix_cache``), takes shared references on those pages,
  and prefills only the suffix — in fixed-size chunks *interleaved with
  decode steps*.  A fully-cached prompt re-runs only its last token; that
  write lands in a shared page and triggers copy-on-write.

**Persistent sessions.**  A paged engine is a long-lived server object: the
page pool, INT4 shadow, Quest metadata, per-slot DS channels, the
``PageAllocator``, and the ``PrefixCache`` radix tree are *engine-lifetime*
state, created on the first admission and reused across calls.  The
streaming API is::

    engine.submit(requests)   # enqueue; reclaims cold tree pages if dry
    engine.step()             # one iteration: admit, prefill 1 chunk, decode
    results = engine.drain()  # harvest finished requests (one host sync)
    engine.reset()            # drop all session state, pool back to free

``generate()`` is a thin wave-compat wrapper (submit + step-until-done +
drain) — successive ``generate()`` calls against one engine therefore hit
and extend the same radix tree, which is what makes the prefix cache pay on
real traffic (per-call pools only helped requests inside one call).

**Sampling is a per-request stream**: token ``j`` of request ``uid`` is
drawn with ``fold_in(fold_in(base_key, uid), j)``, so a request's
continuation is a pure function of its uid and emitted-token index — not of
batch composition, scheduling, or preemption history.  This is what makes
preempted *sampled* requests token-exact (see below) and paged results
reproducible against a fresh engine.  Wave mode keeps its legacy per-step
global stream.

The decode loop stays async in all modes: sampling runs inside the jitted
step, per-step token/budget frames stay on device, and the host fetches
them in ONE sync per :meth:`drain` (frames still referenced by live slots
are kept and rebased).  Host-side work per step is pure bookkeeping on
numpy mirrors of the page table — never a device sync, with two documented
exceptions: the prefix-share admission samples the first token from the
prefill-chunk logits, and **preemption** syncs the victim's emitted tokens.

**True recompute preemption.**  When the pool runs dry mid-decode the
engine preempts the most recently admitted victim: its emitted tokens are
synced to host once, its page references are dropped, and the request is
requeued at the front carrying those tokens.  On re-admission the *prompt*
is re-prefilled as usual (chunked ``prefill_chunk`` under prefix sharing —
typically re-matching the victim's own still-cached pages — one-shot
otherwise), and the generated tokens then **replay through teacher-forced
decode steps**: the slot decodes normally but the sampled token is
overridden by the next recorded one until the replay queue drains.  Forced
decode is the only exact recompute — the original rows were written by the
*pruned* decode path, and full-attention prefill over the same tokens
produces measurably different K/V.  Because sampling is a per-request
stream, the draw at the final forced position lands on exactly the key the
unpreempted engine would have used — so preempted requests are token-exact
whether greedy or sampled (the old restart-from-prompt redrew a sampled
victim's continuation).  Reference counting makes preemption safe by
construction: dropping the victim's references never reclaims a page the
prefix cache or another live reader still holds.  One H2O caveat: a
victim's accumulated page mass is rebuilt by the replay steps themselves,
but mass contributed by its pre-preemption steps to *evicted* pages is
gone — H2O selection, an approximation signal to begin with, may therefore
rank pages slightly differently after a preemption.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import runs as runs_lib
from repro.models import (
    copy_page,
    decode_step,
    decode_step_paged,
    decode_window_paged,
    init_paged_decode_state,
    init_params,
    prefill,
    prefill_chunk,
    supports_chunked_prefill,
    write_prefill_slot,
)
from repro.models.common import ModelConfig
from repro.serving.paged_cache import PageAllocator, pad_to_pages, pages_for
from repro.serving.prefix_cache import PrefixCache
from repro.serving.sampler import sample_token

Tree = Any

_SESSION_COUNTERS = ("preemptions", "prefix_hits", "prefix_tokens",
                     "cow_copies", "evictions", "prefill_chunks")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (s,) int32
    max_new_tokens: int = 32
    greedy: bool = True
    extras: dict | None = None  # modality-frontend embeddings


@dataclasses.dataclass
class GenerationResult:
    uid: int
    tokens: list[int]
    prompt_len: int
    decode_steps: int
    mean_pruned_budget: float
    wall_s: float


@dataclasses.dataclass
class _Pending:
    """Queue entry: a request plus any tokens it already generated before a
    preemption (replayed through prefill on re-admission)."""

    req: Request
    generated: list[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _SlotRun:
    """Host bookkeeping for one admitted request."""

    req: Request
    slot: int
    pages: list[int]
    t_admit: float
    order: int  # admission sequence number (preemption picks the newest)
    tok0: jax.Array | int | None = None  # pending token — sampled or replayed
    start_frame: int = 0  # first decode frame this slot participates in
    emitted: int = 0  # tokens sampled so far (tok0 included)
    prior: list[int] = dataclasses.field(default_factory=list)
    # Remaining teacher-forced tokens of a preempted request's replay (the
    # decode loop overrides the sampled token with the next forced one
    # until the queue drains — reproducing the *pruned* decode path that
    # wrote these rows originally, which full-attention prefill cannot).
    replay: deque[int] | None = None
    # Chunked-prefill progress (prefix-share mode only).
    prompt: np.ndarray | None = None  # truncated prompt (+ replay) tree key
    matched: int = 0  # tokens reused from the prefix cache
    sfx_done: int = 0  # suffix tokens written so far
    ready: bool = True  # prefill complete — slot decodes

    @property
    def suffix_len(self) -> int:
        return 0 if self.prompt is None else len(self.prompt) - self.matched


class DecodeEngine:
    """Batched decode engine around (prefill, decode_step[_paged]).

    Paged engines are persistent sessions — see the module docstring for
    the ``submit``/``step``/``drain``/``reset`` lifecycle.
    """

    def __init__(self, cfg: ModelConfig, params: Tree | None = None, *,
                 batch_size: int = 8, cache_capacity: int = 512, seed: int = 0,
                 paged: bool = False, num_pages: int | None = None,
                 prefix_share: bool = False,
                 prefill_chunk_pages: int = 4,
                 decode_window: int = 1):
        tw = cfg.twilight
        if tw.enabled and tw.compact and tw.pruned_cap_frac is None:
            # Serving default: B1-scaled final gather (ROADMAP follow-up).
            # The attended buffer is re-compacted to 1/4 of the candidate
            # buffer, far above the paper's measured ~2 %-of-n budgets.
            # Only the *staged* backend needs this cap — when
            # ``tw.fused_backend`` resolves to the fused kernel (the TPU
            # default), the whole estimate/top-p/attend tail is one Pallas
            # launch that reads only surviving K/V rows, the cap is ignored
            # (every kept slot is attended, exactly), and
            # ``TwilightOutput.slot_weights`` still arrives for the H2O
            # page-mass scatter-add below.
            cfg = cfg.replace(
                twilight=dataclasses.replace(tw, pruned_cap_frac=0.25))
        self.cfg = cfg
        self.batch_size = batch_size
        self.cache_capacity = cache_capacity
        self.paged = paged
        self.prefix_share = prefix_share
        self.decode_window = decode_window
        if decode_window < 1:
            raise ValueError("decode_window must be >= 1")
        if decode_window > 1:
            if not paged:
                raise ValueError("decode_window > 1 requires paged=True")
            if not supports_chunked_prefill(cfg):
                raise ValueError(
                    f"{cfg.name}: decode_window > 1 requires an "
                    "attention-only stack (supports_chunked_prefill)")
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else init_params(cfg, key)
        self._sample_key = jax.random.PRNGKey(seed + 1)  # wave-mode stream
        self._base_key = jax.random.PRNGKey(seed + 1)  # per-request streams

        self._prefill = jax.jit(
            lambda p, batch: prefill(p, cfg, batch, cache_capacity))
        self._decode = jax.jit(lambda p, st, tok: decode_step(p, cfg, st, tok))

        # Per-call telemetry (reset by generate()) and session totals.
        for name in _SESSION_COUNTERS:
            setattr(self, "last_" + name, 0)
            setattr(self, "session_" + name, 0)
        self.session_submitted = 0
        self.session_completed = 0

        if prefix_share and not paged:
            raise ValueError("prefix_share requires paged=True")
        if paged:
            tw = cfg.twilight
            if not (tw.enabled and tw.compact):
                raise ValueError("paged serving requires the compact "
                                 "Twilight pipeline")
            ps = tw.page_size
            if cache_capacity % ps:
                raise ValueError(f"cache_capacity {cache_capacity} not "
                                 f"divisible by page_size {ps}")
            self.max_pages = cache_capacity // ps
            # Default pool: worst case (every slot full) + the null page —
            # no smaller than wave mode, but callers shrink it to realize
            # the memory win (utilization tracks live tokens, not slots).
            self.num_pages = (num_pages if num_pages is not None
                              else 1 + batch_size * self.max_pages)
            prefix = (cfg.n_prefix_tokens if cfg.frontend == "vision" else 0)
            self._prefill_paged = jax.jit(lambda p, batch: prefill(
                p, cfg, batch,
                pad_to_pages(batch["tokens"].shape[1] + prefix, ps)))
            self._write = jax.jit(
                lambda st, pst, slot, pages: write_prefill_slot(
                    cfg, st, pst, slot, pages),
                donate_argnums=(0,))

            _rs_zero = jnp.zeros((runs_lib.RUN_STATS_LEN,), jnp.float32)

            def _step_fn(p, state, tok, pt, lengths, live, greedy, uids,
                         emitted, base_key):
                logits, state, stats = decode_step_paged(
                    p, cfg, state, tok, pt, lengths, live)
                lg = logits[:, :cfg.vocab_size]

                def samp(uid, e, row, g):
                    k = jax.random.fold_in(
                        jax.random.fold_in(base_key, uid), e)
                    return sample_token(k, row[None], greedy=g)[0]

                nxt = jax.vmap(samp)(uids, emitted, lg, greedy)
                return (nxt, state, stats["pruned_budget"],
                        stats.get("run_stats", _rs_zero))

            self._step_jit = jax.jit(_step_fn, donate_argnums=(1,))

            def _window_fn(p, state, toks, pt, lengths, live, n_tok, greedy,
                           uids, emitted, base_key):
                # toks (b, kw): column 0 is the pending token, columns
                # 1..n_tok-1 are teacher-forced replay tokens.  The sampling
                # row is position n_tok - 1; the draw index is the global
                # emitted-token index of the NEXT token, emitted + n_tok - 1
                # (exactly where n_tok successive single steps would land),
                # so preemption replay stays on the per-request stream.
                logits, state, stats = decode_window_paged(
                    p, cfg, state, toks, pt, lengths, live, n_tok)
                row = jnp.take_along_axis(
                    logits, (n_tok - 1)[:, None, None], axis=1)[:, 0]
                lg = row[:, :cfg.vocab_size]

                def samp(uid, e, r, g):
                    k = jax.random.fold_in(
                        jax.random.fold_in(base_key, uid), e)
                    return sample_token(k, r[None], greedy=g)[0]

                nxt = jax.vmap(samp)(uids, emitted + n_tok - 1, lg, greedy)
                return (nxt, state, stats["pruned_budget"],
                        stats.get("run_stats", _rs_zero))

            self._window_jit = (jax.jit(_window_fn, donate_argnums=(1,))
                                if decode_window > 1 else None)

            if prefix_share:
                if not supports_chunked_prefill(cfg):
                    raise ValueError(
                        f"{cfg.name}: prefix sharing requires an "
                        "attention-only stack — recurrent mixer state is "
                        "prefix-dependent and must be recomputed "
                        "(supports_chunked_prefill)")
                self.chunk_tokens = max(1, prefill_chunk_pages) * ps
                self._chunk = jax.jit(
                    lambda p, st, toks, pt, slot, start, nv, last:
                    prefill_chunk(p, cfg, st, toks, pt, slot, start, nv,
                                  last),
                    donate_argnums=(1,))
                self._copy_page = jax.jit(
                    lambda st, src, dst: copy_page(cfg, st, src, dst),
                    donate_argnums=(0,))

            # Engine-lifetime session state, created on first submit()
            # (the audio encoder length is only known from real requests).
            self._alloc: PageAllocator | None = None
            self._tree: PrefixCache | None = None
            self._state = None  # device pytree: pools + mixer states
            self._n_enc = 0
            self._order = 0
            self._pending: deque[_Pending] = deque()
            self._slots: list[_SlotRun | None] = [None] * batch_size
            self._done: list[tuple[_SlotRun, float]] = []
            self._results: list[GenerationResult] = []
            self._tok_frames: list[jax.Array] = []
            self._budget_frames: list[jax.Array] = []

    # -- dispatch -----------------------------------------------------------

    def generate(self, requests: list[Request]) -> list[GenerationResult]:
        """Serve requests: continuous batching when paged, else waves.

        On a paged engine this is a thin wrapper over the persistent
        ``submit``/``step``/``drain`` session — the pool and prefix tree
        survive between calls, so later calls hit earlier calls' prefixes.
        """
        if self.paged:
            for name in _SESSION_COUNTERS:
                setattr(self, "last_" + name, 0)
            if not requests:
                return []
            uids = {r.uid for r in requests}
            if len(uids) != len(requests):
                raise ValueError("duplicate uids in one generate() call")

            def counts() -> dict[int, int]:
                # Host bookkeeping only — no device sync until the single
                # drain() below.
                c: dict[int, int] = {}
                for run, _ in self._done:
                    c[run.req.uid] = c.get(run.req.uid, 0) + 1
                for r in self._results:
                    c[r.uid] = c.get(r.uid, 0) + 1
                return c

            # Completion = one MORE finished result per uid than before
            # this call, so a stale undrained result buffered under the
            # same uid (submit()/drain() interleaving) can't satisfy it.
            base = counts()
            self.submit(requests)
            while True:
                have = counts()
                if all(have.get(u, 0) > base.get(u, 0) for u in uids):
                    break
                if not self.busy():
                    raise RuntimeError(
                        "engine idle with requests unaccounted for")
                self.step()
            out = self.drain(uids)
            if not any(base.get(u, 0) for u in uids):
                return out
            # A reused uid with an undrained pre-call result (streaming /
            # wrapper mix): return only this call's results — stale ones
            # stay buffered for a later drain().  drain() lists buffered
            # results before newly-finished ones, so the first base[u]
            # per uid are the stale ones.
            seen: dict[int, int] = {}
            mine: list[GenerationResult] = []
            for r in out:
                seen[r.uid] = seen.get(r.uid, 0) + 1
                if seen[r.uid] > base.get(r.uid, 0):
                    mine.append(r)
                else:
                    self._results.append(r)
            return mine
        results: list[GenerationResult] = []
        queue = list(requests)
        while queue:
            wave, queue = self._form_wave(queue)
            results.extend(self._serve_wave(wave))
        return results

    # -- wave mode (the contiguous-cache oracle) ----------------------------

    def _own_keep(self, req: Request) -> int:
        """Prompt tokens request may keep under its *own* decode budget."""
        return max(1, self.cache_capacity - req.max_new_tokens)

    def _form_wave(self, queue: list[Request]
                   ) -> tuple[list[Request], list[Request]]:
        """FIFO wave packing under the shared-position constraint.

        Every slot in a wave appends at the same cache position, so the
        wave must satisfy ``max(kept prompt) + max(max_new) <= capacity``.
        Clipping each prompt to its own ``capacity - max_new`` budget and
        closing the wave when a newcomer would violate the bound means a
        long-prompt/short-generation request is never truncated by a wave
        mate's generation budget (it previously was — the wave-wide
        ``max(max_new_tokens)`` clipped every prompt).
        """
        wave: list[Request] = []
        s = wave_max = 0
        while queue and len(wave) < self.batch_size:
            r = queue[0]
            if r.max_new_tokens >= self.cache_capacity:
                raise ValueError(
                    f"request {r.uid}: max_new_tokens {r.max_new_tokens} "
                    f"cannot fit cache_capacity {self.cache_capacity}")
            ns = max(s, min(len(r.prompt), self._own_keep(r)))
            nmax = max(wave_max, r.max_new_tokens)
            if wave and ns + nmax > self.cache_capacity:
                break
            wave.append(queue.pop(0))
            s, wave_max = ns, nmax
        return wave, queue

    def _serve_wave(self, wave: list[Request]) -> list[GenerationResult]:
        t0 = time.time()
        b = len(wave)
        # Each prompt is clipped by its OWN max_new_tokens; _form_wave
        # guarantees the resulting batch fits the shared cache.
        clipped = [r.prompt[-self._own_keep(r):] for r in wave]
        s = max(len(p) for p in clipped)
        max_new = max(r.max_new_tokens for r in wave)
        assert s + max_new <= self.cache_capacity, "wave packing invariant"
        toks = np.zeros((b, s), np.int32)
        for i, pr in enumerate(clipped):
            toks[i, -len(pr):] = pr  # left-pad with token 0
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.frontend == "audio":
            frames = np.stack([r.extras["frames"] for r in wave])
            batch["frames"] = jnp.asarray(frames)
        elif self.cfg.frontend == "vision":
            patches = np.stack([r.extras["patches"] for r in wave])
            batch["patches"] = jnp.asarray(patches)

        logits, state = self._prefill(self.params, batch)
        last = logits[:, -1, :self.cfg.vocab_size]  # drop padded vocab rows
        # Per-slot sampling mode: a greedy and a sampling request can share
        # a wave (previously collapsed to all(r.greedy)).  A uniform wave
        # keeps the Python-bool fast path (argmax only — no wasted
        # softmax/top-p work for the common all-greedy case).
        modes = [r.greedy for r in wave]
        greedy = modes[0] if len(set(modes)) == 1 else jnp.asarray(modes)
        # The decode loop stays async: tokens and the budget accumulator
        # live on device and are fetched ONCE per wave.  A float()/asarray()
        # inside the loop would block on the device every token and
        # serialize dispatch against compute.
        out_toks_dev = []
        budget_sum = jnp.zeros((), jnp.float32)
        for step in range(max_new):
            self._sample_key, k = jax.random.split(self._sample_key)
            tok = sample_token(k, last, greedy=greedy)
            out_toks_dev.append(tok)
            last, state, stats = self._decode(self.params, state, tok)
            last = last[:, :self.cfg.vocab_size]
            budget_sum = budget_sum + stats["mean_pruned_budget"]

        out_tokens = (np.stack([np.asarray(t) for t in out_toks_dev], axis=1)
                      if out_toks_dev else np.zeros((b, 0), np.int32))
        mean_budget = float(budget_sum) / max_new if max_new else 0.0
        wall = time.time() - t0
        results = []
        for i, r in enumerate(wave):
            results.append(GenerationResult(
                uid=r.uid,
                tokens=out_tokens[i, :r.max_new_tokens].tolist(),
                prompt_len=len(r.prompt),
                decode_steps=r.max_new_tokens,
                mean_pruned_budget=mean_budget,
                wall_s=wall,
            ))
        return results

    # -- continuous mode: persistent session --------------------------------

    def _bump(self, name: str, n: int = 1) -> None:
        setattr(self, "last_" + name, getattr(self, "last_" + name) + n)
        setattr(self, "session_" + name,
                getattr(self, "session_" + name) + n)

    def _ensure_session(self, requests: list[Request]) -> None:
        cfg = self.cfg
        if cfg.frontend == "audio":
            n_enc = len(requests[0].extras["frames"])
            if any(len(r.extras["frames"]) != n_enc for r in requests):
                raise ValueError("audio requests must share a frame length")
            if self._alloc is not None and n_enc != self._n_enc:
                raise ValueError(
                    f"audio frame length {n_enc} differs from the session's "
                    f"{self._n_enc} — call reset() first")
            self._n_enc = n_enc
        if self._alloc is not None:
            return
        b = self.batch_size
        self._alloc = PageAllocator(self.num_pages)
        self._tree = (PrefixCache(cfg.twilight.page_size, self._alloc)
                      if self.prefix_share else None)
        self._state = init_paged_decode_state(cfg, b, self.num_pages,
                                              n_enc=self._n_enc)
        self._pt = np.zeros((b, self.max_pages), np.int32)
        self._lengths = np.zeros((b,), np.int32)
        self._live = np.zeros((b,), bool)
        self._greedy = np.ones((b,), bool)
        self._uids = np.zeros((b,), np.int32)
        self._emitted = np.zeros((b,), np.int32)
        self._cur_tok = jnp.zeros((b,), jnp.int32)
        # Survivor-run telemetry: device-side running sum (no per-step
        # host sync), harvested by session_run_stats().
        self._rs_sum = jnp.zeros((runs_lib.RUN_STATS_LEN,), jnp.float32)
        self._rs_steps = 0

    def busy(self) -> bool:
        """True while the session holds queued or in-flight requests."""
        if not self.paged or self._alloc is None:
            return False
        return bool(self._pending) or any(r is not None for r in self._slots)

    def submit(self, requests: list[Request]) -> None:
        """Enqueue requests on the persistent session (paged engines only).

        If the pool is dry — a steady state for a long-lived engine whose
        free pages have all been absorbed by the prefix tree — cold
        refcount-1 tree pages are reclaimed here, ahead of admission, so
        the new work starts by recycling cache instead of falling straight
        through to preemption (eviction previously ran only inside the
        admission pressure path).
        """
        if not self.paged:
            raise ValueError("submit()/step()/drain() require paged=True — "
                             "wave mode serves via generate()")
        if not requests:
            return
        self._ensure_session(requests)
        for r in requests:
            self._pending.append(_Pending(req=r))
        self.session_submitted += len(requests)
        if self._tree is not None and self._alloc.available == 0:
            head = self._pending[0].req
            want = pages_for(len(head.prompt) + 1, self.cfg.twilight.page_size)
            self._bump("evictions", self._tree.evict(want))

    def _reclaim(self, want: int) -> None:
        """Pool pressure: evict cold prefix-cache pages before anything
        drastic.  No-op when sharing is off or the tree has no refcount-1
        pages."""
        if self._tree is not None and want > 0:
            self._bump("evictions", self._tree.evict(want))

    def _sample_req(self, logits_row: jax.Array, req: Request,
                    idx: int) -> jax.Array:
        """Draw token ``idx`` of ``req``'s per-request sampling stream.

        The uid is folded mod 2^31-1 — the same mapping the jitted step
        applies to its i32 uid array — so the admission-time draw and the
        in-step draws belong to one stream."""
        k = jax.random.fold_in(
            jax.random.fold_in(self._base_key, req.uid % (2 ** 31 - 1)), idx)
        return sample_token(k, logits_row[None], greedy=req.greedy)[0]

    def _batch_one(self, req: Request, prompt: np.ndarray) -> dict:
        batch = {"tokens": jnp.asarray(prompt[None])}
        if self.cfg.frontend == "audio":
            batch["frames"] = jnp.asarray(req.extras["frames"][None])
        elif self.cfg.frontend == "vision":
            batch["patches"] = jnp.asarray(req.extras["patches"][None])
        return batch

    def _chunk_bucket(self, n: int) -> int:
        """Smallest power-of-two multiple of page_size >= n tokens, capped
        at the configured chunk length — the handful of jit signatures the
        chunked-prefill path compiles."""
        ps = self.cfg.twilight.page_size
        c = ps
        while c < min(n, self.chunk_tokens):
            c *= 2
        return min(c, self.chunk_tokens)

    def _truncate(self, req: Request, prefix: int) -> np.ndarray:
        """Clip the prompt so prompt + generation fits the cache capacity."""
        prompt = np.asarray(req.prompt, np.int32)
        cap = self.cache_capacity - prefix
        if req.max_new_tokens >= cap:
            raise ValueError(
                f"request {req.uid}: max_new_tokens "
                f"{req.max_new_tokens} cannot fit cache_capacity "
                f"{self.cache_capacity} (prefix {prefix})")
        keep = cap - req.max_new_tokens  # >= 1
        return prompt[-keep:] if len(prompt) > keep else prompt

    def _sync_generated(self, run: _SlotRun) -> list[int]:
        """Host-sync every token ``run`` has emitted so far — the one
        mid-loop device sync, paid once per preemption.

        ``prior + [tok0]`` covers everything up to the resumption point (a
        run preempted again mid-replay simply re-carries its full original
        list — the frame range below is empty then); real sampled frames
        follow from ``start_frame``."""
        if run.tok0 is None:
            return list(run.prior)
        toks = list(run.prior) + [int(np.asarray(run.tok0))]
        n_frames = run.emitted - len(run.prior) - 1
        if n_frames > 0:
            frames = self._tok_frames[run.start_frame:
                                      run.start_frame + n_frames]
            toks.extend(np.asarray(jnp.stack(frames))[:, run.slot].tolist())
        return toks

    def _go_live(self, run: _SlotRun, s_total: int) -> None:
        slot = run.slot
        run.ready = True
        run.emitted = 1  # the pending token (sampled tok0 or first replay)
        run.start_frame = len(self._tok_frames)
        if self._tree is not None and run.prompt is not None:
            ps = self.cfg.twilight.page_size
            self._tree.insert(run.prompt,
                              run.pages[:len(run.prompt) // ps])
        if run.req.max_new_tokens <= len(run.prior) + 1:
            # Fresh max_new=1 request — or a replay that already covers the
            # whole budget: everything to emit is known, retire instantly.
            self._alloc.free(run.pages)
            self._slots[slot] = None
            self._pt[slot] = 0
            self._done.append((run, time.time()))
            self.session_completed += 1
            return
        self._lengths[slot] = s_total
        self._live[slot] = True
        self._greedy[slot] = run.req.greedy
        self._uids[slot] = run.req.uid % (2 ** 31 - 1)
        self._emitted[slot] = run.emitted
        cur = run.replay[0] if run.replay else run.tok0
        self._cur_tok = self._cur_tok.at[slot].set(cur)

    def _admit(self, slot: int) -> bool:
        """Unshared admission: one-shot contiguous prefill of the *prompt*
        scattered into freshly-allocated pages (the token-exactness oracle
        for the prefix-share path).  A preempted request's generated tokens
        are NOT prefilled — they replay through teacher-forced decode
        steps, because the original rows were written by the *pruned*
        decode path and full-attention prefill would recompute them
        differently."""
        cfg = self.cfg
        ps = cfg.twilight.page_size
        prefix = cfg.n_prefix_tokens if cfg.frontend == "vision" else 0
        pend = self._pending[0]
        req = pend.req
        prompt = self._truncate(req, prefix)
        s_total = len(prompt) + prefix
        worst = pages_for(s_total + req.max_new_tokens, ps)
        if worst > self._alloc.capacity:
            raise ValueError(
                f"request {req.uid} needs {worst} pages; pool has "
                f"{self._alloc.capacity} — raise num_pages")
        n_req = pages_for(s_total, ps)
        live_count = sum(1 for r in self._slots if r is not None)
        # Alone, a request is admitted only if its worst case fits (it
        # then completes without preemption — no livelock); alongside
        # live slots, keep one boundary page of headroom per slot.
        need = worst if live_count == 0 else n_req + live_count
        if self._alloc.available < need:
            return False
        self._pending.popleft()
        pages = self._alloc.alloc(n_req)
        logits, pstate = self._prefill_paged(
            self.params, self._batch_one(req, prompt))
        self._state = self._write(self._state, pstate, jnp.int32(slot),
                                  jnp.asarray(pages, jnp.int32))
        if pend.generated:
            tok0: jax.Array | int = pend.generated[-1]
            prior = pend.generated[:-1]
            replay = deque(pend.generated)
        else:
            tok0 = self._sample_req(
                logits[0, s_total - 1, :cfg.vocab_size], req, 0)
            prior, replay = [], None
        run = _SlotRun(req=req, slot=slot, pages=pages, tok0=tok0,
                       t_admit=time.time(), order=self._order, prior=prior,
                       replay=replay)
        self._order += 1
        self._slots[slot] = run
        self._pt[slot, :n_req] = pages
        self._pt[slot, n_req:] = 0
        self._go_live(run, s_total)
        return True

    def _admit_shared(self, slot: int, use_cache: bool = True) -> bool:
        """Prefix-share admission: match the longest page-aligned cached
        prefix, take shared references, and stage the suffix for chunked
        prefill.  A fully-cached prompt keeps its last token as the suffix
        (its logits seed sampling); that token's write hits a shared page,
        which is exactly the copy-on-write append.  A preempted request's
        prompt typically re-matches its own still-cached pages; its
        generated tokens then replay through teacher-forced decode steps
        (see :meth:`_admit`)."""
        cfg = self.cfg
        ps = cfg.twilight.page_size
        pend = self._pending[0]
        req = pend.req
        prompt = self._truncate(req, 0)
        s_total = len(prompt)
        worst = pages_for(s_total + req.max_new_tokens, ps)
        if worst > self._alloc.capacity:
            raise ValueError(
                f"request {req.uid} needs {worst} pages; pool has "
                f"{self._alloc.capacity} — raise num_pages")
        pages_m, matched = (self._tree.match(prompt) if use_cache
                            else ([], 0))
        cow = False
        if matched == s_total:
            matched -= 1  # re-run the last token for its logits
            cow = True
        n_new = pages_for(s_total, ps) - len(pages_m) + (1 if cow else 0)
        live_count = sum(1 for r in self._slots if r is not None)
        need = (worst - len(pages_m) + (1 if cow else 0)
                if live_count == 0 else n_new + live_count)
        if self._alloc.available < need:
            self._reclaim(need - self._alloc.available)
        if self._alloc.available < need:
            if pages_m:
                self._alloc.free(pages_m)
            if live_count == 0 and use_cache:
                # Alone and still short: the match itself may pin the
                # pool (e.g. worst == capacity and the COW page cannot
                # fit).  Retry cold — eviction can then reclaim
                # everything, and worst <= capacity guarantees admission.
                return self._admit_shared(slot, use_cache=False)
            return False
        self._pending.popleft()
        if matched:
            self._bump("prefix_hits")
            self._bump("prefix_tokens", matched)
        if cow:
            src = pages_m[-1]
            new, copied = self._alloc.cow(src)
            if copied:
                self._state = self._copy_page(self._state, jnp.int32(src),
                                              jnp.int32(new))
                self._bump("cow_copies")
            pages_m = pages_m[:-1] + [new]
        run = _SlotRun(req=req, slot=slot, pages=list(pages_m),
                       t_admit=time.time(), order=self._order, prompt=prompt,
                       matched=matched, ready=False,
                       prior=pend.generated[:-1],
                       tok0=(pend.generated[-1] if pend.generated else None),
                       replay=(deque(pend.generated) if pend.generated
                               else None))
        self._order += 1
        self._slots[slot] = run
        self._pt[slot, :len(run.pages)] = run.pages
        self._pt[slot, len(run.pages):] = 0
        self._lengths[slot] = 0
        self._live[slot] = False
        return True

    def _retire(self, slot: int, preempted: bool = False) -> None:
        run = self._slots[slot]
        if preempted:
            # True recompute preemption: carry the emitted tokens back to
            # the queue (host-synced here) so re-admission replays them.
            self._pending.appendleft(
                _Pending(req=run.req, generated=self._sync_generated(run)))
        self._alloc.free(run.pages)
        self._slots[slot] = None
        self._live[slot] = False
        self._pt[slot] = 0
        self._lengths[slot] = 0
        # Reset the sampling mode so a freed slot doesn't carry its
        # previous occupant's mode into the jitted step before
        # re-admission (greedy is the junk-safe default: no stray
        # top-p draw for a dead slot).
        self._greedy[slot] = True
        self._uids[slot] = 0
        self._emitted[slot] = 0
        if not preempted:
            self._done.append((run, time.time()))
            self.session_completed += 1

    def _preempt_for_page(self, needy: int) -> None:
        victims = [r for r in (self._slots[s] for s in range(self.batch_size))
                   if r is not None and r.slot != needy]
        victim = (max(victims, key=lambda r: r.order).slot
                  if victims else needy)
        self._bump("preemptions")
        self._retire(victim, preempted=True)

    def _ensure_pages(self, need: int, needy: int) -> bool:
        """Make ``need`` pages available for slot ``needy``: evict cold
        tree pages first, then preempt newest-first — re-trying eviction
        after every preemption, since retiring a victim whose pages are
        tree-shared frees nothing directly but exposes those pages for
        reclaim.  Returns False if ``needy`` itself was preempted (last
        resort)."""
        if self._alloc.available < need:
            self._reclaim(need - self._alloc.available)
        while self._alloc.available < need:
            self._preempt_for_page(needy)
            if self._alloc.available < need:
                self._reclaim(need - self._alloc.available)
            if self._slots[needy] is None:
                return False
        return True

    def _advance_prefill(self, run: _SlotRun) -> None:
        """Write one (bucketed) chunk of ``run``'s suffix into pool pages;
        completing the suffix flips the slot live (sampling tok0 from the
        chunk logits, unless a replayed token is already pending)."""
        cfg = self.cfg
        ps = cfg.twilight.page_size
        slot = run.slot
        start = run.matched + run.sfx_done
        remaining = run.suffix_len - run.sfx_done
        n_valid = min(remaining, self.chunk_tokens)
        c = self._chunk_bucket(n_valid)  # >= n_valid by construction
        need = pages_for(start + n_valid, ps) - len(run.pages)
        if need > 0:
            if (not self._ensure_pages(need, slot)
                    or self._slots[slot] is not run):
                return  # self-preempted
            new_pages = self._alloc.alloc(need)
            self._pt[slot, len(run.pages):len(run.pages) + need] = new_pages
            run.pages.extend(new_pages)
        toks = np.zeros((c,), np.int32)
        toks[:n_valid] = run.prompt[start:start + n_valid]
        is_last = run.sfx_done + n_valid >= run.suffix_len
        logits, self._state, pstats = self._chunk(
            self.params, self._state, jnp.asarray(toks),
            jnp.asarray(self._pt[slot]), jnp.int32(slot), jnp.int32(start),
            jnp.int32(n_valid), jnp.asarray(is_last))
        self._bump("prefill_chunks")
        if self.cfg.twilight.collect_run_stats:
            # Sparse-prefill live-page telemetry accumulates into the same
            # session vector as the decode run stats (disjoint slots, so
            # the decode summaries are unchanged); chunks do not count as
            # decode steps.
            self._rs_sum = self._rs_sum + pstats["prefill_run_stats"]
        run.sfx_done += n_valid
        if run.sfx_done >= run.suffix_len:
            if run.tok0 is None:
                run.tok0 = self._sample_req(
                    logits[0, n_valid - 1, :cfg.vocab_size], run.req, 0)
            self._go_live(run, len(run.prompt))

    def step(self) -> int:
        """One engine iteration: admit into free slots, advance one
        prefilling slot by one chunk, allocate boundary pages, run one
        jitted decode step, retire finished slots.  Returns the number of
        finished requests awaiting :meth:`drain`."""
        if not self.paged:
            raise ValueError("step() requires paged=True")
        if self._alloc is None:
            return 0
        b = self.batch_size
        ps = self.cfg.twilight.page_size
        # Admission: fill every free slot while the queue and pool allow
        # (an instantly-retired max_new=1 request frees its slot again).
        slot = 0
        while self._pending and slot < b:
            if self._slots[slot] is None:
                ok = (self._admit_shared(slot) if self.prefix_share
                      else self._admit(slot))
                if not ok:
                    break
                if self._slots[slot] is None:
                    continue
            slot += 1
        # Advance ONE prefilling slot by one chunk, oldest first —
        # interleaving admission work with decode steps bounds the decode
        # stall a long admission can cause to one chunk.
        prefilling = [r for r in self._slots if r is not None and not r.ready]
        if prefilling:
            self._advance_prefill(min(prefilling, key=lambda r: r.order))
        if not any(self._live):
            return len(self._done) + len(self._results)
        kw = self.decode_window
        # Window occupancy: slot i decodes n_tok[i] tokens this step — the
        # pending token plus up to kw-1 queued replay tokens (teacher-forced
        # through the SAME k-token window path, so a preempted request's
        # recompute is token-exact AND takes fewer launches).
        n_tok = np.ones((b,), np.int32)
        forced = np.zeros((b, kw), np.int32)
        if kw > 1:
            for slot in range(b):
                run = self._slots[slot]
                if self._live[slot] and run.replay:
                    w = min(len(run.replay), kw)
                    n_tok[slot] = w
                    forced[slot, :w] = [run.replay[j] for j in range(w)]
        # Boundary pages for this step's appends (every window position
        # that opens a fresh page needs one).
        for slot in range(b):
            if not self._live[slot]:
                continue
            for pos in range(self._lengths[slot],
                             self._lengths[slot] + n_tok[slot]):
                if pos % ps != 0:
                    continue
                if not self._ensure_pages(1, slot) or not self._live[slot]:
                    break  # self-preempted (last resort)
                page = self._alloc.alloc(1)[0]
                self._slots[slot].pages.append(page)
                self._pt[slot, pos // ps] = page
        if not any(self._live):
            return len(self._done) + len(self._results)
        # One jitted step for the whole batch; dead slots compute junk
        # into the null page.
        if kw > 1:
            toks = jnp.concatenate(
                [self._cur_tok[:, None], jnp.asarray(forced[:, 1:])], axis=1)
            self._cur_tok, self._state, budget, rs = self._window_jit(
                self.params, self._state, toks, jnp.asarray(self._pt),
                jnp.asarray(self._lengths), jnp.asarray(self._live),
                jnp.asarray(n_tok), jnp.asarray(self._greedy),
                jnp.asarray(self._uids), jnp.asarray(self._emitted),
                self._base_key)
        else:
            self._cur_tok, self._state, budget, rs = self._step_jit(
                self.params, self._state, self._cur_tok,
                jnp.asarray(self._pt), jnp.asarray(self._lengths),
                jnp.asarray(self._live), jnp.asarray(self._greedy),
                jnp.asarray(self._uids), jnp.asarray(self._emitted),
                self._base_key)
        self._tok_frames.append(self._cur_tok)
        self._budget_frames.append(budget)
        if self.cfg.twilight.collect_run_stats:
            self._rs_sum = self._rs_sum + rs  # device-side, no sync
            self._rs_steps += 1
        for slot in range(b):
            if not self._live[slot]:
                continue
            w = int(n_tok[slot])
            self._lengths[slot] += w
            run = self._slots[slot]
            run.emitted += w
            self._emitted[slot] = run.emitted
            if run.replay:
                # Teacher-forced replay of a preempted request: the w
                # tokens just written came off the queue; while more
                # remain, override the sampled token with the next forced
                # one.  (The per-request key stream makes the draw at the
                # final forced position land exactly where the oracle's
                # would.)
                for _ in range(w):
                    run.replay.popleft()
                if run.replay:
                    self._cur_tok = self._cur_tok.at[slot].set(
                        run.replay[0])
                    run.start_frame = len(self._tok_frames)
                    continue
                run.replay = None
            if run.emitted >= run.req.max_new_tokens:
                self._retire(slot)
        return len(self._done) + len(self._results)

    def drain(self, uids: set[int] | None = None) -> list[GenerationResult]:
        """Harvest finished requests (one host sync for all pending
        frames).  With ``uids`` only matching results are returned; the
        rest stay buffered for a later drain.  Frames still referenced by
        live slots are kept on device and rebased."""
        if not self.paged or self._alloc is None:
            return []
        harvested = list(self._results)
        if self._done:
            # One host sync, bounded to the frames the finished runs need —
            # frames only live slots reference stay on device untouched.
            need = max(r.start_frame + r.req.max_new_tokens - len(r.prior) - 1
                       for r, _ in self._done)
            need = min(max(need, 0), len(self._tok_frames))
            toks = (np.asarray(jnp.stack(self._tok_frames[:need]))
                    if need else np.zeros((0, self.batch_size), np.int32))
            buds = (np.asarray(jnp.stack(self._budget_frames[:need]))
                    if need else np.zeros((0, self.batch_size), np.float32))
            for run, t_done in self._done:
                n_dec = run.req.max_new_tokens - len(run.prior) - 1
                frames = toks[run.start_frame:run.start_frame + n_dec,
                              run.slot]
                frame_buds = buds[run.start_frame:run.start_frame + n_dec,
                                  run.slot]
                harvested.append(GenerationResult(
                    uid=run.req.uid,
                    tokens=(list(run.prior) + [int(np.asarray(run.tok0))]
                            + frames.tolist()),
                    prompt_len=len(run.req.prompt),
                    decode_steps=run.req.max_new_tokens,
                    mean_pruned_budget=(float(frame_buds.mean())
                                        if len(frame_buds) else 0.0),
                    wall_s=t_done - run.t_admit,
                ))
            self._done = []
        # Compact the frame buffer: drop frames no live run references.
        starts = [r.start_frame for r in self._slots
                  if r is not None and r.ready]
        keep_from = min(starts, default=len(self._tok_frames))
        if keep_from:
            del self._tok_frames[:keep_from]
            del self._budget_frames[:keep_from]
            for r in self._slots:
                if r is not None and r.ready:
                    r.start_frame -= keep_from
        if uids is None:
            self._results = []
            return harvested
        self._results = [r for r in harvested if r.uid not in uids]
        return [r for r in harvested if r.uid in uids]

    def session_run_stats(self) -> dict | None:
        """Session-lifetime survivor-run telemetry (one host sync).

        Returns :func:`repro.core.runs.summarize_run_stats` of the summed
        per-step vectors — run-length histogram, runs/pages/kept per step —
        or None when ``cfg.twilight.collect_run_stats`` is off or no decode
        step has run.  Counts are summed over attention layers."""
        if (not self.paged or self._alloc is None or self._rs_steps == 0
                or not self.cfg.twilight.collect_run_stats):
            return None
        return runs_lib.summarize_run_stats(np.asarray(self._rs_sum),
                                            self._rs_steps)

    def reset(self) -> None:
        """Tear the session down: live slots and the pending queue are
        dropped (their requests are NOT completed), undrained results are
        discarded, every prefix-tree reference is released — the allocator
        must come back fully-free (a refcount leak raises) — and the
        device pools themselves are released.  The next ``submit()``
        rebuilds the session from scratch (which is also what lets an
        audio engine accept a different encoder frame length)."""
        if not self.paged or self._alloc is None:
            return
        for slot in range(self.batch_size):
            run = self._slots[slot]
            if run is not None:
                self._alloc.free(run.pages)
                self._slots[slot] = None
        self._pending.clear()
        self._done.clear()
        self._results.clear()
        self._tok_frames.clear()
        self._budget_frames.clear()
        if self._tree is not None:
            self._tree.clear()
        leaked = self._alloc.capacity - self._alloc.available
        self._alloc = None
        self._tree = None
        self._state = None
        self._n_enc = 0
        if leaked:
            raise RuntimeError(
                f"page leak on reset: {leaked} pages still referenced — "
                "refcounts out of balance")
