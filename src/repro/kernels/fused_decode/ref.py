"""Pure-jnp oracle for the fused decode kernel.

Composes the staged stages in code space — INT4 estimate from the packed
codes (the spgemv math, f32 throughout, no bf16 dequant round-trip),
masked softmax, Algorithm-1 binary search, exact attention over every kept
slot — so the fused kernel's outputs can be checked stage-for-stage.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import topp as topp_lib
from repro.core.attention import compact_decode_attention, gather_kv_heads
from repro.core.quant import QuantizedTensor
from repro.kernels.fused_decode.kernel import coalesce_block


def page_survivor_blocks(valid: jax.Array, m: int,
                         page_size: int) -> jax.Array:
    """Block-granularity page-survivor mask, (..., m // blk) bool.

    The shared derivation the fused kernel's hierarchical stage 1 uses:
    a block is alive iff any of its ``blk = coalesce_block(m, page_size)``
    candidate slots is valid.  Because the selectors mark every slot of a
    nucleus-pruned page invalid, this equals the page-nucleus survivor set
    at block granularity.
    """
    blk = coalesce_block(m, page_size)
    return valid.reshape(*valid.shape[:-1], m // blk, blk).any(axis=-1)


def fused_prune_attend_ref(
    q: jax.Array,  # (b, hq, d)
    indices: jax.Array,  # (b, hkv, m) i32
    valid: jax.Array,  # (b, hkv, m) bool
    keys: jax.Array,  # (b, n, hkv, d) or (P, hkv, d)
    values: jax.Array,
    qkeys: QuantizedTensor,  # INT4 shadow, same layout as keys
    *,
    p: jax.Array | float,
    iters: int = 24,
    page_size: int | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    b, hq, d = q.shape
    hkv, m = indices.shape[1], indices.shape[2]
    group = hq // hkv
    sm_scale = 1.0 / (d ** 0.5)

    packed = gather_kv_heads(qkeys.packed, indices)  # (b, hkv, m, d2)
    scale = gather_kv_heads(qkeys.scale, indices)[..., 0].astype(jnp.float32)
    zero = gather_kv_heads(qkeys.zero, indices)[..., 0].astype(jnp.float32)
    low = (packed & 0x0F).astype(jnp.float32)
    high = (packed >> 4).astype(jnp.float32)

    qg = q.reshape(b, hkv, group, d).astype(jnp.float32)
    qe, qo = qg[..., 0::2], qg[..., 1::2]
    dot = jnp.einsum("bhgc,bhmc->bhgm", qe, low)
    dot += jnp.einsum("bhgc,bhmc->bhgm", qo, high)
    qsum = jnp.sum(qg, axis=-1)[..., None]  # (b, hkv, g, 1)
    est = (dot * scale[:, :, None, :] + qsum * zero[:, :, None, :]) * sm_scale

    if page_size is not None:
        # Hierarchical contract pin: dead-block estimates are zero (the
        # kernel's stage-1 early-out never computes them).  A no-op for
        # the outputs — every dead-block slot is invalid, so the masked
        # softmax drops it either way — but it keeps the oracle
        # bit-for-bit comparable to the kernel's raw estimate stage.
        palive = page_survivor_blocks(valid, m, page_size)  # (b, hkv, nb)
        blk = m // palive.shape[-1]
        slot_live = jnp.repeat(palive, blk, axis=-1)  # (b, hkv, m)
        est = jnp.where(slot_live[:, :, None, :], est, 0.0)

    valid_g = jnp.broadcast_to(valid[:, :, None, :], est.shape)
    w = topp_lib.masked_softmax(est, valid_g)
    res = topp_lib.topp_mask(w, p, iters=iters)
    kept = (res.mask & valid_g).any(axis=2)  # (b, hkv, m) group union

    kg = gather_kv_heads(keys, indices)
    vg = gather_kv_heads(values, indices)
    out = compact_decode_attention(q, kg, vg, kept)
    return out, kept, w.max(axis=2), res.threshold.reshape(b, hq)


def fused_prune_attend_window_ref(
    q: jax.Array,  # (b, kw, hq, d)
    indices: jax.Array,  # (b, hkv, m) i32 — shared candidate buffer
    valid: jax.Array,  # (b, kw, hkv, m) bool — per-position validity
    keys: jax.Array,
    values: jax.Array,
    qkeys: QuantizedTensor,
    *,
    p: jax.Array | float,
    iters: int = 24,
    page_size: int | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Window oracle: kw independent single-token prune-attends that share
    one candidate buffer — exactly the semantic contract of the multi-token
    kernel (selection anchored once, prune/attend per position)."""
    outs, kepts, ws, ths = [], [], [], []
    for j in range(q.shape[1]):
        o, k, w, t = fused_prune_attend_ref(
            q[:, j], indices, valid[:, j], keys, values, qkeys,
            p=p, iters=iters, page_size=page_size)
        outs.append(o)
        kepts.append(k)
        ws.append(w)
        ths.append(t)
    return (jnp.stack(outs, axis=1), jnp.stack(kepts, axis=1),
            jnp.stack(ws, axis=1), jnp.stack(ths, axis=1))
