"""Accuracy benchmarks — one function per paper table/figure.

All numbers are measured for real on tiny models trained in this container
(Zipf-Markov LM for perplexity tables, needle-retrieval model for the
Longbench/RULER-style tables).  Budgets scale with the context (192-224
tokens here vs 32k in the paper); the *relative* claims being validated are
the paper's: Twilight prunes the base algorithm's over-selection with no
accuracy loss, and p is the stable knob.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import (
    csv_row,
    eval_decode_ppl,
    eval_needle_acc,
    lm_model,
    needle_model,
    twilight_variant,
)
from repro.data import DataConfig, needle_batch, zipf_markov_tokens


def _lm_eval_tokens(cfg, b=8, s=160, seed=123):
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=s, global_batch=b,
                      seed=seed)
    rng = np.random.default_rng(seed)
    return zipf_markov_tokens(dcfg, rng, b)[:, :s]


def fig2_budget_vs_ppl():
    """Fig. 2: PPL vs fixed top-k budget per base algorithm, vs Twilight.

    Reproduces the paper's point that the optimal fixed budget depends on
    the algorithm, while top-p hits the knee adaptively.
    """
    cfg, params = lm_model()
    toks = _lm_eval_tokens(cfg)
    rows = []
    full_ppl, _ = eval_decode_ppl(
        params, twilight_variant(cfg, enabled=False), toks)
    csv_row("fig2_full", 0.0, f"ppl={full_ppl:.3f};budget=159")
    for sel in ("quest", "streaming"):
        for budget in (16, 32, 64, 128):
            c = twilight_variant(cfg, selector=sel, prune_enabled=False,
                                 fixed_budget=budget)
            ppl, b = eval_decode_ppl(params, c, toks)
            rows.append((sel, budget, ppl))
            csv_row(f"fig2_{sel}_k{budget}", 0.0,
                    f"ppl={ppl:.3f};budget={b:.0f}")
    c = twilight_variant(cfg, selector="quest", prune_enabled=True,
                         candidate_frac=0.5, p=0.9)
    ppl, b = eval_decode_ppl(params, c, toks)
    csv_row("fig2_quest_twilight", 0.0, f"ppl={ppl:.3f};budget={b:.1f}")
    return rows


def tab2_longbench_proxy():
    """Table 2: base algorithm @ budget sweep vs +Twilight (retrieval task).

    Score = needle retrieval accuracy; 'Budget' column = mean pruned budget.
    """
    cfg, params = needle_model()
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=160, global_batch=32,
                      seed=7)
    rng = np.random.default_rng(7)
    batch = needle_batch(dcfg, rng, 32)

    results = {}
    acc, _ = eval_needle_acc(params, twilight_variant(cfg, enabled=False),
                             batch)
    results["full"] = acc
    csv_row("tab2_full", 0.0, f"acc={acc:.3f};budget=160")
    acc, b = eval_needle_acc(
        params, twilight_variant(cfg, selector="full", p=0.95,
                                 candidate_frac=1.0), batch)
    results["full_twi"] = acc
    csv_row("tab2_full_twilight", 0.0, f"acc={acc:.3f};budget={b:.1f}")
    for sel in ("quest", "double_sparsity"):
        for budget in (16, 48, 96):
            c = twilight_variant(cfg, selector=sel, prune_enabled=False,
                                 fixed_budget=budget)
            acc, b = eval_needle_acc(params, c, batch)
            csv_row(f"tab2_{sel}_k{budget}", 0.0,
                    f"acc={acc:.3f};budget={b:.0f}")
        c = twilight_variant(cfg, selector=sel, prune_enabled=True,
                             candidate_frac=0.5, p=0.95)
        acc, b = eval_needle_acc(params, c, batch)
        results[f"{sel}_twi"] = acc
        csv_row(f"tab2_{sel}_twilight", 0.0, f"acc={acc:.3f};budget={b:.1f}")
    return results


def tab3_ruler_proxy():
    """Table 3: needle retrieval across context lengths (RULER niah-style).

    Distractor-needle variants need a bigger model/training budget to bind
    the queried key (measured: the 4L/128d model plateaus at chance on
    n_needles=3), so this proxy sweeps context length at one needle —
    the axis the paper's Table 3 varies."""
    cfg, params = needle_model()
    for s in (96, 160):
        dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=s,
                          global_batch=32, seed=11)
        rng = np.random.default_rng(11)
        batch = needle_batch(dcfg, rng, 32, n_needles=1)
        for name, c in [
            ("full", twilight_variant(cfg, enabled=False)),
            ("quest_k32", twilight_variant(cfg, selector="quest",
                                           prune_enabled=False,
                                           fixed_budget=32)),
            ("quest_twi", twilight_variant(cfg, selector="quest", p=0.95,
                                           candidate_frac=0.5)),
            ("ds_twi", twilight_variant(cfg, selector="double_sparsity",
                                        p=0.95, candidate_frac=0.5)),
        ]:
            acc, b = eval_needle_acc(params, c, batch)
            csv_row(f"tab3_{name}_s{s}", 0.0, f"acc={acc:.3f};budget={b:.1f}")


def tab4_medium_context():
    """Table 4: medium-context PPL, pruner-only comparison at budget ~16."""
    cfg, params = lm_model()
    toks = _lm_eval_tokens(cfg, s=128)
    rows = {}
    for name, c in [
        ("full", twilight_variant(cfg, enabled=False)),
        ("quest_k16", twilight_variant(cfg, selector="quest",
                                       prune_enabled=False, fixed_budget=16)),
        ("ds_k16", twilight_variant(cfg, selector="double_sparsity",
                                    prune_enabled=False, fixed_budget=16)),
        ("twilight", twilight_variant(cfg, selector="full", p=0.9,
                                      candidate_frac=1.0)),
    ]:
        ppl, b = eval_decode_ppl(params, c, toks)
        rows[name] = ppl
        csv_row(f"tab4_{name}", 0.0, f"ppl={ppl:.3f};budget={b:.1f}")
    return rows


def fig6_quant_bits():
    """Fig. 6: kept attention mass under estimate precisions, p=0.85."""
    import jax.numpy as jnp

    from repro.core import TwilightPruner, masked_softmax
    cfg, params = lm_model()
    del cfg, params
    rng = np.random.default_rng(3)
    b, hq, hkv, n, d = 4, 4, 2, 512, 64
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    K = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    # Plant focus so the distribution is realistic.
    Kn = np.array(K)
    Kn[:, 13] = 2.5 * np.asarray(q).reshape(b, hkv, 2, d).mean(2)
    K = jnp.asarray(Kn)
    exact_scores = TwilightPruner(estimate_bits=16).estimate_scores(q, K, None)
    w_exact = masked_softmax(exact_scores, None)
    cand = jnp.ones((b, hkv, n), bool)
    for bits, sim_noise in ((2, None), (4, None), (8, None), (16, None)):
        if bits in (4, 16):
            pruner = TwilightPruner(p=0.85, estimate_bits=bits)
            mask, stats = pruner.prune(q, cand, keys=K)
        else:
            # Simulate 2/8-bit by quantizing K at that precision.
            levels = 2 ** bits - 1
            Kf = np.asarray(K)
            lo, hi = Kf.min(-1, keepdims=True), Kf.max(-1, keepdims=True)
            scale = np.maximum((hi - lo) / levels, 1e-8)
            Kq = np.round((Kf - lo) / scale).clip(0, levels) * scale + lo
            pruner = TwilightPruner(p=0.85, estimate_bits=16)
            mask, stats = pruner.prune(q, cand, keys=jnp.asarray(Kq))
        mask_q = jnp.repeat(mask, hq // hkv, axis=1)
        kept = np.where(np.asarray(mask_q), np.asarray(w_exact), 0).sum(-1)
        csv_row(f"fig6_bits{bits}", 0.0,
                f"kept_mass={kept.mean():.4f};budget={float(stats.pruned_budget.mean()):.1f}")


def fig9_p_sensitivity():
    """Fig. 9: PPL and pruned budget (-> latency) as p sweeps."""
    from benchmarks.common import attn_bytes_quest_twi, bytes_to_us
    cfg, params = lm_model()
    toks = _lm_eval_tokens(cfg)
    for p in (0.5, 0.7, 0.8, 0.85, 0.9, 0.95, 0.99):
        c = twilight_variant(cfg, selector="full", p=p, candidate_frac=1.0)
        ppl, b = eval_decode_ppl(params, c, toks)
        # Project the measured budget ratio onto the paper's 32k scenario.
        b1 = int(32768 * b / 160)
        us = bytes_to_us(attn_bytes_quest_twi(32768, 8, 128, 8192, b1))
        csv_row(f"fig9_p{p}", us, f"ppl={ppl:.3f};budget={b:.1f}")


def tabD_token_dropping():
    """Appendix D: token-dropping (StreamingLLM-style) vs token-selecting
    (+Twilight) on the retrieval task — dropping loses the needle whenever
    it falls outside sink+recent; Twilight keeps it."""
    cfg, params = needle_model()
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=160, global_batch=32,
                      seed=17)
    rng = np.random.default_rng(17)
    batch = needle_batch(dcfg, rng, 32)
    for name, c in [
        ("streaming_k48", twilight_variant(cfg, selector="streaming",
                                           prune_enabled=False,
                                           fixed_budget=48)),
        ("streaming_k96", twilight_variant(cfg, selector="streaming",
                                           prune_enabled=False,
                                           fixed_budget=96)),
        ("h2o_k48", twilight_variant(cfg, selector="h2o",
                                     prune_enabled=False, fixed_budget=48)),
        ("ds_twilight", twilight_variant(cfg, selector="double_sparsity",
                                         p=0.95, candidate_frac=0.5)),
    ]:
        try:
            acc, b = eval_needle_acc(params, c, batch)
            csv_row(f"tabD_{name}", 0.0, f"acc={acc:.3f};budget={b:.1f}")
        except ValueError as e:
            csv_row(f"tabD_{name}", 0.0, f"skipped={e}")
