"""Top-p machinery: oracle vs binary search + invariants (hypothesis)."""

import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional dep: property tests degrade to fixed sweeps without it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    HAVE_HYPOTHESIS = False

from repro.core.topp import (
    masked_softmax,
    oracle_topp_mask,
    topp_mask,
    topp_threshold,
)
from tests.conftest import make_weights


@pytest.mark.parametrize("p", [0.5, 0.8, 0.9, 0.95, 0.99])
@pytest.mark.parametrize("concentration", [0.5, 3.0, 8.0])
def test_binary_search_matches_oracle(rng, p, concentration):
    w = make_weights(rng, 16, 512, concentration)
    oracle = oracle_topp_mask(jnp.asarray(w), p)
    bs = topp_mask(jnp.asarray(w), p)
    np.testing.assert_array_equal(np.asarray(oracle.budget),
                                  np.asarray(bs.budget))
    np.testing.assert_array_equal(np.asarray(oracle.mask), np.asarray(bs.mask))


def test_coverage_and_minimality(rng):
    w = make_weights(rng, 32, 256, 4.0)
    p = 0.9
    res = topp_mask(jnp.asarray(w), p)
    kept = np.where(np.asarray(res.mask), w, 0.0).sum(-1)
    assert (kept >= p - 1e-6).all(), "top-p mask must cover p"
    # Minimality: removing the smallest kept weight must drop below p.
    w_masked = np.where(np.asarray(res.mask), w, np.inf)
    smallest_kept = w_masked.min(-1)
    assert (kept - smallest_kept < p + 1e-6).all(), "mask must be minimal"


def _topp_invariants(n, p, conc, seed):
    rng = np.random.default_rng(seed)
    w = make_weights(rng, 4, n, conc)
    res = topp_mask(jnp.asarray(w), p)
    mask = np.asarray(res.mask)
    kept = np.where(mask, w, 0.0).sum(-1)
    # Coverage.
    assert (kept >= p - 1e-5).all()
    # The max-weight token is always kept.
    assert mask[np.arange(4), w.argmax(-1)].all()
    # Threshold consistency: every kept weight >= threshold.
    thr = np.asarray(res.threshold)
    assert (np.where(mask, w, np.inf) >= thr[:, None] - 1e-7).all()


def _monotone_in_p(seed):
    rng = np.random.default_rng(seed)
    w = make_weights(rng, 4, 128, 3.0)
    budgets = [int(topp_mask(jnp.asarray(w), p).budget.sum())
               for p in (0.5, 0.7, 0.9, 0.99)]
    assert budgets == sorted(budgets), "budget must be monotone in p"


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(
        n=st.integers(8, 300),
        p=st.floats(0.1, 0.99),
        conc=st.floats(0.1, 10.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property_topp_invariants(n, p, conc, seed):
        _topp_invariants(n, p, conc, seed)

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_property_monotone_in_p(seed):
        _monotone_in_p(seed)
else:
    @pytest.mark.parametrize("n", [8, 33, 300])
    @pytest.mark.parametrize("p", [0.1, 0.9, 0.99])
    @pytest.mark.parametrize("conc,seed", [(0.1, 0), (3.0, 1), (10.0, 2)])
    def test_property_topp_invariants(n, p, conc, seed):
        _topp_invariants(n, p, conc, seed)

    @pytest.mark.parametrize("seed", [0, 7, 1234567])
    def test_property_monotone_in_p(seed):
        _monotone_in_p(seed)


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_p_extremes(rng, impl):
    """p→0 collapses to the argmax token; p=1.0 keeps every token with
    positive weight — in the reference and the Pallas row-kernel alike."""
    if impl == "pallas":
        from repro.kernels.topp.ops import topp_mask as mask_fn
    else:
        mask_fn = topp_mask
    # Scale fractionally below 1 so the row's fp sum is strictly < p=1.0:
    # whether the mass reaches exactly 1.0 is an ulp-level accident of the
    # summation order; "p unreachable -> keep everything" is the pinned
    # semantic (the pipeline's masked_softmax rows behave the same way).
    w = jnp.asarray(make_weights(rng, 8, 256, 3.0) * (1 - 1e-6))[None]
    lo = mask_fn(w, 1e-9)
    mask = np.asarray(lo.mask)[0]
    wn = np.asarray(w)[0]
    assert mask[np.arange(8), wn.argmax(-1)].all()
    assert (np.asarray(lo.budget) >= 1).all()
    # Ties at the max are measure-zero for random weights: argmax only.
    assert (np.asarray(lo.budget) == 1).all()
    hi = mask_fn(w, 1.0)
    assert np.asarray(hi.mask)[0].all(), "p=1.0 keeps the whole row"


def test_pallas_topp_matches_jnp_on_masked_rows(rng):
    """Rows with zero weights (masked-out candidates) agree between the
    Pallas kernel and the reference, including an all-zero row."""
    from repro.kernels.topp.ops import topp_mask as pallas_mask
    w = make_weights(rng, 8, 128, 3.0)
    w[:, 64:] = 0.0  # half the row masked out
    w[3] = 0.0  # a fully-masked row
    wj = jnp.asarray(w)[None]
    ref = topp_mask(wj, 0.9)
    pal = pallas_mask(wj, 0.9)
    np.testing.assert_array_equal(np.asarray(ref.mask), np.asarray(pal.mask))
    np.testing.assert_allclose(np.asarray(ref.threshold),
                               np.asarray(pal.threshold), rtol=1e-6,
                               atol=1e-9)


def test_adaptive_budget_focused_vs_diffuse(rng):
    """The paper's core claim: focused attention needs far fewer tokens."""
    focused = make_weights(rng, 8, 1024, 8.0)
    diffuse = make_weights(rng, 8, 1024, 0.3)
    bf = int(topp_mask(jnp.asarray(focused), 0.9).budget.mean())
    bd = int(topp_mask(jnp.asarray(diffuse), 0.9).budget.mean())
    assert bf * 4 < bd, f"focused {bf} should be <<< diffuse {bd}"


def test_threshold_fixed_iters_resolution(rng):
    w = make_weights(rng, 8, 256, 3.0)
    t24 = topp_threshold(jnp.asarray(w), 0.9, iters=24)
    t40 = topp_threshold(jnp.asarray(w), 0.9, iters=40)
    assert float(jnp.max(jnp.abs(t24 - t40))) < 1e-6


def test_masked_softmax_fully_masked_rows():
    scores = jnp.ones((2, 4))
    mask = jnp.zeros((2, 4), bool)
    out = masked_softmax(scores, mask)
    assert not np.isnan(np.asarray(out)).any()
    assert (np.asarray(out) == 0).all()


def test_masked_softmax_matches_softmax():
    rng = np.random.default_rng(1)
    s = jnp.asarray(rng.normal(size=(3, 64)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(masked_softmax(s, None)),
        np.asarray(jnp.exp(s) / jnp.exp(s).sum(-1, keepdims=True)),
        rtol=1e-5)
