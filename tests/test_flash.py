"""Flash (blockwise custom-VJP) attention vs reference, fwd + bwd."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import mha_attention
from repro.models.flash import _choose_block, flash_attention


@pytest.mark.parametrize("s,n,q_block", [(256, 256, 64), (128, 384, 128),
                                         (512, 512, 512)])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_forward(rng, s, n, q_block, hq, hkv, causal):
    if causal and s != n:
        pytest.skip("causal requires aligned q/kv here")
    d = 64
    q = jnp.asarray(rng.normal(size=(2, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, n, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, n, hkv, d)), jnp.float32)
    out = flash_attention(q, k, v, causal, q_block, 0)
    ref = mha_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_gradients(rng):
    b, s, hq, hkv, d = 2, 192, 8, 2, 32
    q = jnp.asarray(rng.normal(size=(b, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)

    def loss_flash(q, k, v):
        return jnp.sum(jnp.tanh(flash_attention(q, k, v, True, 64, 0)))

    def loss_ref(q, k, v):
        return jnp.sum(jnp.tanh(mha_attention(q, k, v, causal=True)))

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-3, atol=1e-4)


def test_flash_block_choice_prime_length():
    # Regression: the old divisor search (`while s % q_block: q_block -= 1`)
    # collapsed the tile to 1 for prime lengths like 8191, serializing the
    # whole scan.  Pad-and-mask keeps the preferred block.
    assert _choose_block(8191, 512) == 512
    assert _choose_block(257, 64) == 64
    assert _choose_block(16, 64) == 16  # short seq: cap at s


@pytest.mark.parametrize("s", [257, 191])
def test_flash_odd_length_forward(rng, s):
    d, hq, hkv = 32, 4, 2
    q = jnp.asarray(rng.normal(size=(2, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, s, hkv, d)), jnp.float32)
    out = flash_attention(q, k, v, True, 64, 0)
    ref = mha_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_odd_length_gradients(rng):
    b, s, hq, hkv, d = 1, 131, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(b, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)

    def loss_flash(q, k, v):
        return jnp.sum(jnp.tanh(flash_attention(q, k, v, True, 64, 0)))

    def loss_ref(q, k, v):
        return jnp.sum(jnp.tanh(mha_attention(q, k, v, causal=True)))

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-3, atol=1e-4)


def test_flash_bf16(rng):
    q = jnp.asarray(rng.normal(size=(1, 256, 4, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.bfloat16)
    out = flash_attention(q, k, v, True, 128, 0)
    assert out.dtype == jnp.bfloat16
    ref = mha_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2)
