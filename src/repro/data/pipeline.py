"""Synthetic data pipeline.

Two corpora, both deterministic given a seed and generated on the host in
numpy (no jax allocations until sharding):

* **Zipf-Markov LM** — tokens follow a Zipfian unigram prior mixed with a
  first-order Markov "phrase" structure, giving a learnable next-token
  signal (a ~100M model drops loss quickly) while keeping entropy realistic.
  Used for the perplexity-style benchmarks (PG-19 stand-in).

* **Needle retrieval** — long filler contexts with embedded (key, value)
  pairs and a final query; exact-match accuracy of the generated value is
  the long-context retrieval metric (RULER/Longbench stand-in).  Sparse
  attention quality is directly visible on this task: focused attention on
  the needle is what top-p keeps and top-k over/under-selects around.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    n_states: int = 256  # Markov phrase states


def _zipf_probs(vocab: int, a: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-a)
    return p / p.sum()


_SUCC_PROBS = np.array([0.5, 0.25, 0.15, 0.10])


def _successor_table(vocab: int) -> np.ndarray:
    """Fixed per-vocab first-order Markov successor table (v, 4).

    Depends ONLY on the vocab so every DataConfig seed shares one
    "language" — train and eval streams must be mutually predictable
    (sampling randomness comes from the caller's rng)."""
    r = np.random.default_rng(0x5EED + vocab)
    return r.integers(0, vocab, size=(vocab, len(_SUCC_PROBS)))


def zipf_markov_tokens(cfg: DataConfig, rng: np.random.Generator,
                       batch: int) -> np.ndarray:
    """(batch, seq_len+1) int32 order-1 Markov token stream.

    Each token has 4 fixed likely successors (probs .5/.25/.15/.1) plus 10%
    Zipf-distributed noise: per-token entropy ~2.2 nats, so a competent LM
    reaches ppl ~10 while unigram-only models stay near ~vocab.  The
    successor table is a deterministic function of the vocab alone, so all
    seeds (train and eval streams) share the same language.
    """
    v, s = cfg.vocab_size, cfg.seq_len + 1
    succ = _successor_table(v)
    zipf = _zipf_probs(v, cfg.zipf_a)
    toks = np.empty((batch, s), np.int64)
    toks[:, 0] = rng.choice(v, size=batch, p=zipf)
    choice = rng.choice(len(_SUCC_PROBS), size=(batch, s), p=_SUCC_PROBS)
    noise_mask = rng.random((batch, s)) < 0.10
    noise = rng.choice(v, size=(batch, s), p=zipf)
    for t in range(1, s):
        nxt = succ[toks[:, t - 1], choice[:, t]]
        toks[:, t] = np.where(noise_mask[:, t], noise[:, t], nxt)
    return toks.astype(np.int32)


def synthetic_lm_batches(cfg: DataConfig, steps: int
                         ) -> Iterator[dict[str, np.ndarray]]:
    """Yield {"tokens", "labels"} host batches; labels are next tokens."""
    rng = np.random.default_rng(cfg.seed)
    for _ in range(steps):
        toks = zipf_markov_tokens(cfg, rng, cfg.global_batch)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def needle_batch(cfg: DataConfig, rng: np.random.Generator, batch: int,
                 *, n_needles: int = 1) -> dict[str, np.ndarray]:
    """Retrieval task: ... KEY k ... VALUE v ... QUERY k -> expect v.

    Token roles: [0, 8) control tokens; filler draws from the lower half of
    the vocab and keys/values from the upper half — disjoint ranges, so the
    query key's only other occurrence is at its needle (a clean induction
    signal; with shared ranges chance filler collisions poison the copy
    circuit and the task never trains at this scale).
    Returns tokens (batch, seq_len) and the expected value ids (batch,).
    """
    v, s = cfg.vocab_size, cfg.seq_len
    KEY_MARK, QUERY_MARK = 1, 2
    mid = 8 + (v - 8) // 2
    filler = rng.integers(8, mid, size=(batch, s))
    keys = np.stack([rng.choice(np.arange(mid, v), size=n_needles,
                                replace=False) for _ in range(batch)])
    vals = rng.integers(mid, v, size=(batch, n_needles))
    tokens = filler.copy()
    # Place needles uniformly in [s//8, 6*s//8); query goes at the end.
    for i in range(batch):
        pos = rng.choice(np.arange(s // 8, 6 * s // 8, 3), size=n_needles,
                         replace=False)
        for j, p in enumerate(pos):
            tokens[i, p] = KEY_MARK
            tokens[i, p + 1] = keys[i, j]
            tokens[i, p + 2] = vals[i, j]
    tokens[:, -2] = QUERY_MARK
    tokens[:, -1] = keys[:, 0]
    return {"tokens": tokens.astype(np.int32),
            "answers": vals[:, 0].astype(np.int32)}


def batch_for_arch(cfg_model, data_cfg: DataConfig, rng: np.random.Generator
                   ) -> dict[str, np.ndarray]:
    """A host train batch including modality-frontend stub embeddings."""
    toks = zipf_markov_tokens(data_cfg, rng, data_cfg.global_batch)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg_model.frontend == "audio":
        batch["frames"] = rng.normal(size=(
            data_cfg.global_batch, data_cfg.seq_len, cfg_model.d_model)
        ).astype(np.float32)
    elif cfg_model.frontend == "vision":
        batch["patches"] = rng.normal(size=(
            data_cfg.global_batch, cfg_model.n_prefix_tokens, cfg_model.d_model)
        ).astype(np.float32)
    return batch
