"""Beyond-paper feature: INT4-reuse final attention (paper §4.3 future work)."""

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import (
    TwilightConfig,
    attention_error,
    full_decode_attention,
    quantize_int4,
    twilight_decode_attention,
)


def test_int4_final_attention_close(rng):
    b, hq, hkv, n, d = 2, 8, 2, 512, 64
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    K = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    qkeys = quantize_int4(K)

    base = TwilightConfig(selector="full", p=0.95, candidate_frac=1.0,
                          page_size=64)
    out_fp = twilight_decode_attention(q, K, V, base, qkeys=qkeys)
    out_i4 = twilight_decode_attention(
        q, K, V, dataclasses.replace(base, reuse_int4_for_attention=True),
        qkeys=qkeys)
    exact = full_decode_attention(q, K, V)
    err_fp = float(attention_error(exact, out_fp.out).max())
    err_i4 = float(attention_error(exact, out_i4.out).max())
    vf = float(jnp.linalg.norm(V[0, :, 0]))
    # INT4-final stays within ~2x of the fp16-final error and the bound.
    assert err_i4 <= max(2.5 * err_fp, 0.1 * vf), (err_fp, err_i4)
