"""Public wrappers for sparse decode attention.

* :func:`masked_attention` — mask-driven kernel over the full cache layout.
* :func:`compact_attention` — the compact-pipeline hot path: runs the
  kernel directly on pre-gathered (b, hkv, m, d) candidate buffers (as
  produced by ``repro.core.attention.gather_kv_heads``).
* :func:`gathered_attention` — convenience: candidate pages are first
  compacted (gather) into a (B, B0) buffer so HBM traffic scales with the
  *candidate* budget, then the kernel applies the top-p mask inside.  This
  mirrors the paper's hierarchy: selector bounds traffic, pruner bounds
  compute.
* :func:`paged_attention` — the same, but gathering from the shared KV page
  pool at physical rows pre-translated through a page table (the
  continuous-batching serving path).

``interpret`` resolution is centralized in ``repro.kernels.common``: every
wrapper and kernel defaults to ``None`` → ``default_interpret()``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.sparse_attn.kernel import sparse_decode_attention


def _to_bhkv(x: jax.Array) -> jax.Array:
    """(b, n, hkv, d) -> (b*hkv, n, d)."""
    b, n, hkv, d = x.shape
    return jnp.moveaxis(x, 2, 1).reshape(b * hkv, n, d)


def masked_attention(
    q: jax.Array,  # (b, hq, d)
    keys: jax.Array,  # (b, n, hkv, d)
    values: jax.Array,  # (b, n, hkv, d)
    mask: jax.Array,  # (b, hkv, n) bool — pruned set
    *,
    sm_scale: float | None = None,
    block_n: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    b, hq, d = q.shape
    hkv = keys.shape[2]
    group = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    qg = q.reshape(b, hkv, group, d).reshape(b * hkv, group, d)
    out = sparse_decode_attention(
        qg,
        _to_bhkv(keys),
        _to_bhkv(values),
        mask.reshape(b * hkv, -1),
        sm_scale=float(sm_scale),
        block_n=block_n,
        interpret=interpret,
    )
    return out.reshape(b, hq, d)


def compact_attention(
    q: jax.Array,  # (b, hq, d)
    k_gathered: jax.Array,  # (b, hkv, m, d) — pre-gathered candidate K
    v_gathered: jax.Array,  # (b, hkv, m, d) — pre-gathered candidate V
    valid: jax.Array,  # (b, hkv, m) bool — live slots AND top-p kept
    *,
    sm_scale: float | None = None,
    block_n: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Kernel over pre-gathered candidate buffers (everything O(m))."""
    b, hkv, m, d = k_gathered.shape
    hq = q.shape[1]
    group = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    qg = q.reshape(b, hkv, group, d).reshape(b * hkv, group, d)
    out = sparse_decode_attention(
        qg,
        k_gathered.reshape(b * hkv, m, d),
        v_gathered.reshape(b * hkv, m, d),
        valid.reshape(b * hkv, m),
        sm_scale=float(sm_scale),
        block_n=block_n,
        interpret=interpret,
    )
    return out.reshape(b, hq, d)


def paged_attention(
    q: jax.Array,  # (b, hq, d)
    k_pool: jax.Array,  # (num_pages * page_size, hkv, d) shared pool
    v_pool: jax.Array,  # (num_pages * page_size, hkv, d)
    phys_indices: jax.Array,  # (b, hkv, m) i32 physical pool rows
    valid: jax.Array,  # (b, hkv, m) bool — live slots AND top-p kept
    *,
    sm_scale: float | None = None,
    block_n: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Paged-pool variant of :func:`gathered_attention`: candidate rows are
    gathered from the shared page pool at pre-translated physical indices
    (``repro.core.selectors.physical_token_indices``), then the kernel runs
    on the compacted O(m) buffer."""
    from repro.core.attention import gather_kv_heads

    kg = gather_kv_heads(k_pool, phys_indices)  # (b, hkv, m, d)
    vg = gather_kv_heads(v_pool, phys_indices)
    return compact_attention(q, kg, vg, valid, sm_scale=sm_scale,
                             block_n=block_n, interpret=interpret)


def gathered_attention(
    q: jax.Array,  # (b, hq, d)
    keys: jax.Array,  # (b, n, hkv, d)
    values: jax.Array,  # (b, n, hkv, d)
    indices: jax.Array,  # (b, hkv, m) i32 candidate positions (selector output)
    valid: jax.Array,  # (b, hkv, m) bool — live slots AND top-p kept
    *,
    sm_scale: float | None = None,
    block_n: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Compact candidates first, then run the kernel on the small buffer."""
    kh = jnp.moveaxis(keys, 2, 1)  # (b, hkv, n, d)
    vh = jnp.moveaxis(values, 2, 1)
    kg = jnp.take_along_axis(kh, indices[..., None], axis=2)  # (b, hkv, m, d)
    vg = jnp.take_along_axis(vh, indices[..., None], axis=2)
    return compact_attention(q, kg, vg, valid, sm_scale=sm_scale,
                             block_n=block_n, interpret=interpret)
