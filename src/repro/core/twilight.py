"""Twilight: the hierarchical Select-then-Prune pipeline (§4.1, Figure 5).

    q, KV cache ──► Token Selector (base algo, conservative B0)
                  ──► Twilight Pruner (INT4 estimate + top-p)
                  ──► Sparse Attention Kernel (pruned set only)

The pipeline is a pure function over arrays so it jits/shards/scans freely;
stateful concerns (paged cache, INT4 shadow cache maintenance, H2O stats)
live in ``repro.serving``.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import quant as quant_lib
from repro.core.attention import full_decode_attention, masked_sparse_decode_attention
from repro.core.pruner import PrunerStats, TwilightPruner
from repro.core.selectors import (
    SelectionContext,
    TokenSelector,
    selector_from_name,
)

__all__ = ["TwilightConfig", "TwilightOutput", "twilight_decode_attention"]


@dataclasses.dataclass(frozen=True)
class TwilightConfig:
    """Configuration of the full pipeline.

    ``candidate_frac`` is the conservative Token Selector sparsity (paper
    suggests 1/4); ``candidate_budget_cap`` bounds B0 absolutely so 500k+
    contexts stay tractable (pages-worth of tokens, see DESIGN §5).
    """

    enabled: bool = True
    selector: str = "quest"
    p: float = 0.95
    candidate_frac: float = 0.25
    candidate_budget_cap: int = 65536
    page_size: int = 64
    estimate_bits: int = 4
    topp_iters: int = 24
    min_candidate: int = 64
    # prune_enabled=False degrades the pipeline to the *base algorithm
    # alone* (pure top-k: Quest/DS/... without the Twilight Pruner) — the
    # paper's baselines.  fixed_budget overrides candidate_frac with an
    # absolute token budget (the paper's budget-sweep rows).
    prune_enabled: bool = True
    fixed_budget: int = 0
    # Beyond-paper (suggested in §4.3 as future work): compute the *final*
    # attention against the INT4 shadow K instead of the fp16 K cache —
    # halves the final K read and, combined with offloading, removes the
    # need to keep fp16 K resident at all.  V stays full precision.
    reuse_int4_for_attention: bool = False

    def candidate_budget(self, n: int) -> int:
        if self.fixed_budget:
            return min(self.fixed_budget, n)
        b0 = int(n * self.candidate_frac)
        b0 = max(self.min_candidate, min(b0, self.candidate_budget_cap))
        return min(b0, n)

    def make_selector(self, **kwargs) -> TokenSelector:
        return selector_from_name(self.selector, **kwargs)

    def make_pruner(self) -> TwilightPruner:
        return TwilightPruner(p=self.p, iters=self.topp_iters,
                              estimate_bits=self.estimate_bits)


class TwilightOutput(NamedTuple):
    out: jax.Array  # (b, hq, d)
    candidate_mask: jax.Array  # (b, hkv, n)
    pruned_mask: jax.Array  # (b, hkv, n)
    stats: PrunerStats


def twilight_decode_attention(
    q: jax.Array,  # (b, hq, d)
    keys: jax.Array,  # (b, n, hkv, d)
    values: jax.Array,  # (b, n, hkv, d)
    cfg: TwilightConfig,
    *,
    ctx: SelectionContext | None = None,
    qkeys: quant_lib.QuantizedTensor | None = None,
    length: jax.Array | None = None,
) -> TwilightOutput:
    """One decode-step of Twilight-optimized sparse attention.

    When ``cfg.enabled`` is False this degrades to exact full attention with
    trivial masks/stats — the "Full" baseline rows of Tables 2–4.
    """
    b, n, hkv, d = keys.shape
    hq = q.shape[1]

    if not cfg.enabled:
        out = full_decode_attention(q, keys, values, length=length)
        ones = jnp.ones((b, hkv, n), bool)
        stats = PrunerStats(
            candidate_budget=jnp.full((b, hkv), n, jnp.int32),
            pruned_budget=jnp.full((b, hkv), n, jnp.int32),
            threshold=jnp.zeros((b, hq), jnp.float32),
            weights=jnp.zeros((b, hq, n), jnp.float32),
        )
        return TwilightOutput(out=out, candidate_mask=ones, pruned_mask=ones,
                              stats=stats)

    if ctx is None:
        # Ergonomic fallback: derive selector metadata from the keys.  The
        # serving engine maintains these incrementally instead.
        from repro.core.selectors import build_page_meta, calibrate_ds_channels
        pm = (build_page_meta(keys, cfg.page_size)
              if n % cfg.page_size == 0 else None)
        ds = (calibrate_ds_channels(keys, 16)
              if cfg.selector in ("ds", "double_sparsity") else None)
        ctx = SelectionContext(keys=keys, page_meta=pm, accum_scores=None,
                               length=length, ds_channels=ds)

    selector = cfg.make_selector()
    b0 = cfg.candidate_budget(n)
    candidate_mask = selector.select(q, ctx, b0)  # (b, hkv, n)

    if not cfg.prune_enabled:
        # Base algorithm alone (pure top-k baseline rows of Tables 2-4).
        pruned_mask = candidate_mask
        stats = PrunerStats(
            candidate_budget=candidate_mask.sum(-1).astype(jnp.int32),
            pruned_budget=candidate_mask.sum(-1).astype(jnp.int32),
            threshold=jnp.zeros((b, hq), jnp.float32),
            weights=jnp.zeros((b, hq, n), jnp.float32),
        )
    else:
        pruner = cfg.make_pruner()
        pruned_mask, stats = pruner.prune(q, candidate_mask, keys=keys,
                                          qkeys=qkeys)

    attn_keys = keys
    if cfg.reuse_int4_for_attention and qkeys is not None:
        attn_keys = quant_lib.dequantize_int4(qkeys, dtype=keys.dtype)
    out = masked_sparse_decode_attention(q, attn_keys, values, pruned_mask)
    return TwilightOutput(out=out, candidate_mask=candidate_mask,
                          pruned_mask=pruned_mask, stats=stats)
