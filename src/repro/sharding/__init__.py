from repro.sharding.rules import (
    MeshAxes,
    batch_specs,
    decode_state_specs,
    logits_spec,
    opt_state_specs,
    paged_decode_state_specs,
    param_specs,
)

__all__ = [
    "MeshAxes",
    "batch_specs",
    "decode_state_specs",
    "logits_spec",
    "opt_state_specs",
    "paged_decode_state_specs",
    "param_specs",
]
