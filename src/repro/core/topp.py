"""Top-p (nucleus) selection over attention weights.

Implements the paper's two formulations:

* :func:`oracle_topp_mask` — Definition 3.3, the sort-based oracle that keeps
  the minimal set of indices whose weights sum to ``>= p``.
* :func:`topp_mask` — Algorithm 1, the parallel-friendly binary search over a
  weight threshold.  This is the form the Pallas kernel implements; the pure
  JAX version here is the distributed/reference path (all reductions lower to
  exact all-reduces when the row is sharded).

Weights are *normalized* attention weights (post-softmax), possibly restricted
to a candidate subset (the Token Selector's output).  All functions are
batched over arbitrary leading dims; the token axis is the last axis.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "ToppResult",
    "masked_softmax",
    "oracle_topp_mask",
    "topp_mask",
    "topp_threshold",
]


class ToppResult(NamedTuple):
    """Result of a top-p pruning pass."""

    mask: jax.Array  # bool (..., n) — kept indices
    threshold: jax.Array  # f32 (...,) — weight threshold actually applied
    budget: jax.Array  # i32 (...,) — number of kept tokens per row


def masked_softmax(scores: jax.Array, mask: jax.Array | None, axis: int = -1) -> jax.Array:
    """Numerically-stable softmax restricted to ``mask`` (True = participate).

    Fully-masked rows return all-zeros rather than NaNs.
    """
    if mask is not None:
        neg = jnp.finfo(scores.dtype).min
        scores = jnp.where(mask, scores, neg)
    m = jax.lax.stop_gradient(jnp.max(scores, axis=axis, keepdims=True))
    unnorm = jnp.exp(scores - m)
    if mask is not None:
        unnorm = jnp.where(mask, unnorm, 0.0)
    denom = jnp.sum(unnorm, axis=axis, keepdims=True)
    return unnorm / jnp.maximum(denom, jnp.finfo(scores.dtype).tiny)


def oracle_topp_mask(weights: jax.Array, p: float) -> ToppResult:
    """Definition 3.3: minimal index set with cumulative weight >= p.

    Sort-based; O(n log n).  Used as the test oracle and in the accuracy
    benchmarks.  Ties at the threshold weight are all kept (superset of a
    minimal set; identical for distinct weights).
    """
    w = weights.astype(jnp.float32)
    sorted_w = jnp.sort(w, axis=-1)[..., ::-1]
    csum = jnp.cumsum(sorted_w, axis=-1)
    # First position where the prefix sum reaches p -> minimal count.
    reached = csum >= jnp.asarray(p, jnp.float32)
    # If p is unreachable (weights sum < p), keep everything.
    k = jnp.where(
        jnp.any(reached, axis=-1),
        jnp.argmax(reached, axis=-1) + 1,
        w.shape[-1],
    )
    thresh = jnp.take_along_axis(sorted_w, (k - 1)[..., None], axis=-1)[..., 0]
    mask = w >= thresh[..., None]
    return ToppResult(mask=mask, threshold=thresh, budget=jnp.sum(mask, axis=-1))


@functools.partial(jax.jit, static_argnames=("iters",))
def topp_threshold(weights: jax.Array, p: jax.Array, iters: int = 24) -> jax.Array:
    """Algorithm 1: binary-search the largest threshold ``l`` such that
    ``sum(weights[weights >= l]) >= p``.

    ``iters`` fixed iterations instead of an epsilon stopping rule — 24
    halvings on weights in [0, 1] resolve the threshold to ~6e-8, far below
    any attention-weight gap we care about, and keep the loop trip count
    static for TPU.
    """
    w = weights.astype(jnp.float32)
    p = jnp.asarray(p, jnp.float32)
    lo = jnp.zeros(w.shape[:-1], jnp.float32)
    hi = jnp.max(w, axis=-1)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        kept = jnp.sum(jnp.where(w >= mid[..., None], w, 0.0), axis=-1)
        ok = kept >= p
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return lo


def topp_mask(
    weights: jax.Array,
    p: jax.Array | float,
    *,
    iters: int = 24,
    min_keep: int = 1,
) -> ToppResult:
    """Binary-search top-p mask (Algorithm 1).

    ``min_keep`` guards degenerate rows: the max-weight token is always kept
    (lo starts at 0, so this holds by construction for min_keep=1).
    """
    del min_keep  # max token always survives: threshold <= max(weights).
    thresh = topp_threshold(weights, p, iters=iters)
    mask = weights >= thresh[..., None]
    return ToppResult(
        mask=mask,
        threshold=thresh,
        budget=jnp.sum(mask, axis=-1).astype(jnp.int32),
    )
