"""Partitioning rules for the production mesh (DESIGN §4).

Axes:
  * ``model`` — tensor parallelism: heads / d_ff / experts / vocab.
  * ``data``  — batch parallelism AND FSDP over the non-tensor dim of every
    ≥2-D parameter (keeps Jamba-398B's Adam state under 10 GB/chip).
  * ``pod``   — second data axis in the multi-pod mesh; joins the FSDP axes
    so cross-pod traffic is gradient reduce-scatter + param all-gather.

Rules are name+shape driven over the param tree paths; decode-state rules
additionally depend on (batch, kv_heads) divisibility — when heads cannot
shard over ``model`` the cache shards its *sequence* dim instead
(flash-decoding), and long_500k (batch=1) sequence-shards over every axis.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import PartitionSpec as P

from repro.models.common import ModelConfig

Tree = Any


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Logical axis groups for a concrete mesh."""

    fsdp: tuple[str, ...]  # ("data",) or ("pod", "data")
    tensor: str = "model"
    batch: tuple[str, ...] = ()  # defaults to fsdp

    def __post_init__(self):
        if not self.batch:
            object.__setattr__(self, "batch", self.fsdp)

    @classmethod
    def for_mesh(cls, mesh: jax.sharding.Mesh) -> "MeshAxes":
        names = mesh.axis_names
        if "pod" in names:
            return cls(fsdp=("pod", "data"))
        return cls(fsdp=("data",))

    def sizes(self, mesh: jax.sharding.Mesh) -> tuple[int, int]:
        fsdp = 1
        for a in self.fsdp:
            fsdp *= mesh.shape[a]
        return fsdp, mesh.shape[self.tensor]


def _divisible(dim: int, size: int) -> bool:
    return size > 0 and dim % size == 0


def _fsdp_if(axes: MeshAxes, mesh, dim: int):
    if not axes.fsdp:
        return None
    fsdp_size, _ = axes.sizes(mesh)
    return axes.fsdp if _divisible(dim, fsdp_size) else None


def _tensor_if(axes: MeshAxes, mesh, dim: int):
    _, t = axes.sizes(mesh)
    return axes.tensor if _divisible(dim, t) else None


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _param_leaf_spec(name: str, shape: tuple[int, ...], axes: MeshAxes,
                     mesh) -> P:
    """Rule table keyed on the leaf name (last path component)."""
    nd = len(shape)
    t = lambda d: _tensor_if(axes, mesh, d)  # noqa: E731
    f = lambda d: _fsdp_if(axes, mesh, d)  # noqa: E731

    if nd <= 1:
        # Biases/norm scales: shard 'model-ish' vectors when large.
        if name in ("bq", "bk", "bv", "conv_b", "dt_bias", "D", "b_gates") \
                and shape and shape[0] >= 1024:
            return P(t(shape[0]))
        return P()

    if name == "embed":  # (V, d): vocab -> model, d -> fsdp
        return P(t(shape[0]), f(shape[1]))
    if name == "lm_head":  # (d, V)
        return P(f(shape[0]), t(shape[1]))
    if name in ("wq", "wk", "wv", "wi", "wg", "up", "in_proj", "w_gates",
                "skip_gate", "w_if"):
        if nd == 3:  # MoE experts (E, d, d_e): experts -> model, d -> fsdp
            return P(t(shape[0]), f(shape[1]), None)
        return P(f(shape[0]), t(shape[1]))
    if name in ("wo", "down", "out_proj", "dt_proj"):
        if nd == 3:  # MoE (E, d_e, d)
            return P(t(shape[0]), None, f(shape[2]))
        return P(t(shape[0]), f(shape[1]))
    if name == "router":  # (d, E) — small, replicate
        return P()
    if name == "conv_w":  # (k, d_inner)
        return P(None, t(shape[1]))
    if name in ("x_proj", "A_log"):  # (d_inner, r)
        return P(t(shape[0]), None)
    if name == "r_gates":  # (4, nh, dh, dh) — small block-diagonal, replicate
        return P()
    # Fallback: shard the largest dim over tensor, next over fsdp.
    order = sorted(range(nd), key=lambda i: -shape[i])
    spec = [None] * nd
    if shape[order[0]] >= 1024:
        spec[order[0]] = t(shape[order[0]])
    return P(*spec)


def param_specs(params: Tree, cfg: ModelConfig, mesh, *,
                layout: str = "fsdp") -> Tree:
    """PartitionSpec tree matching ``params``.

    layout:
      * "fsdp"       — tensor dim over `model`, complementary dim over the
        fsdp axes (training default; Adam states inherit it).
      * "model_only" — tensor dim over `model` only; no fsdp dim.  The
        inference layout: weights stay resident per chip (P/16), no
        per-step shard gathers or partial-sum all-reduces over `data`.

    Stacked block params carry a leading repeats dim -> prefix None.
    """
    axes = MeshAxes.for_mesh(mesh)
    if layout == "model_only":
        axes = MeshAxes(fsdp=(), tensor=axes.tensor, batch=axes.batch)

    def spec_for(path, leaf):
        ps = _path_str(path)
        name = ps.split("/")[-1]
        shape = leaf.shape
        stacked = "/blocks/" in f"/{ps}" or ps.startswith("blocks/")
        if stacked and len(shape) >= 1:
            inner = _param_leaf_spec(name, shape[1:], axes, mesh)
            return P(None, *inner)
        return _param_leaf_spec(name, shape, axes, mesh)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def opt_state_specs(opt_state: Tree, params_spec: Tree) -> Tree:
    """Adam m/v mirror the param sharding; step is replicated."""
    return {
        "m": params_spec,
        "v": params_spec,
        "step": P(),
    }


def batch_specs(batch: Tree, axes: MeshAxes) -> Tree:
    """Host batch: leading (global batch) dim over the batch axes."""
    def spec(path, leaf):
        del path
        nd = len(leaf.shape)
        return P(axes.batch, *([None] * (nd - 1)))

    return jax.tree_util.tree_map_with_path(spec, batch)


def logits_spec(axes: MeshAxes, mesh, vocab: int, *, ndim: int = 3) -> P:
    t = _tensor_if(axes, mesh, vocab)
    if ndim == 3:
        return P(axes.batch, None, t)
    return P(axes.batch, t)


# ---------------------------------------------------------------------------
# Decode state sharding
# ---------------------------------------------------------------------------

def _kv_layout(axes: MeshAxes, mesh, batch: int, seq: int, heads: int,
               *, kv_seq_shard: bool = True) -> tuple[Any, Any, Any]:
    """(batch_axis, seq_axis, head_axis) for cache tensors (b, n, h, d)."""
    fsdp_size, t_size = axes.sizes(mesh)
    if batch == 1:
        # long-context single request: pure sequence parallelism over
        # every available axis (flash-decoding collectives).
        all_axes = tuple([*axes.fsdp, axes.tensor])
        total = fsdp_size * t_size
        if _divisible(seq, total):
            return None, all_axes, None
        return None, axes.tensor if _divisible(seq, t_size) else None, None
    b_ax = axes.batch if _divisible(batch, fsdp_size) else None
    if _divisible(heads, t_size):
        return b_ax, None, axes.tensor
    # GQA heads too few for the model axis: shard the sequence instead
    # (flash-decoding), unless disabled — batch-only replicates the cache
    # over `model` but avoids the seq<->head reshard traffic.
    if kv_seq_shard:
        return b_ax, (axes.tensor if _divisible(seq, t_size) else None), None
    return b_ax, None, None


def decode_state_specs(state: Tree, cfg: ModelConfig, mesh, *,
                       batch: int, capacity: int,
                       kv_seq_shard: bool = True) -> Tree:
    axes = MeshAxes.for_mesh(mesh)
    b_ax, s_ax, h_ax = _kv_layout(axes, mesh, batch, capacity,
                                  cfg.n_kv_heads, kv_seq_shard=kv_seq_shard)
    fsdp_size, t_size = axes.sizes(mesh)
    page_cap = capacity // cfg.twilight.page_size if cfg.twilight.enabled else 0
    p_ax = s_ax if (s_ax and page_cap and _page_div(page_cap, s_ax, mesh)) else None

    def spec(path, leaf):
        ps = _path_str(path)
        name = ps.split("/")[-1]
        shape = leaf.shape
        stacked = ps.startswith("blocks/")
        inner = shape[1:] if stacked else shape

        def wrap(*s):
            return P(None, *s) if stacked else P(*s)

        if name in ("k", "v"):
            return wrap(b_ax, s_ax, h_ax, None)
        if name in ("qk_packed", "qk_scale", "qk_zero"):
            return wrap(b_ax, s_ax, h_ax, None)
        if name in ("pmax", "pmin"):
            return wrap(b_ax, p_ax, h_ax, None)
        if name == "h2o_mass":  # (b, n_pages, hkv) page-granular H2O mass
            return wrap(b_ax, p_ax, h_ax)
        if name in ("cross_k", "cross_v"):
            return wrap(b_ax, None, h_ax, None)
        if name == "ds_channels":
            return wrap(*([None] * len(inner)))
        if name == "ssm":  # (b, d_inner, d_state)
            return wrap(b_ax, _tensor_if(axes, mesh, inner[1]), None)
        if name == "conv":  # (b, k-1, d_inner)
            return wrap(b_ax, None, _tensor_if(axes, mesh, inner[2]))
        if name in ("C", "n", "m", "c", "h"):  # xLSTM states
            rest = [None] * (len(inner) - 1)
            return wrap(b_ax, *rest)
        if name == "pos":
            return P()
        rest = [None] * max(0, len(inner) - 1)
        return wrap(b_ax, *rest)

    return jax.tree_util.tree_map_with_path(spec, state)


def _page_div(page_cap: int, s_ax, mesh) -> bool:
    size = 1
    axes = s_ax if isinstance(s_ax, tuple) else (s_ax,)
    for a in axes:
        if a is not None:
            size *= mesh.shape[a]
    return page_cap % size == 0


def paged_decode_state_specs(state: Tree, cfg: ModelConfig, mesh, *,
                             batch: int, num_pages: int) -> Tree:
    """PartitionSpec tree for the *paged* decode state (shared page pool).

    The pooled attention caches ``(num_pages * page_size, hkv, d)`` shard
    their token-row dim over ``model`` — pages must not straddle shards, so
    the pool is sharded only when ``num_pages`` divides by the tensor size
    (each shard then holds ``num_pages // t`` whole pages; Quest metadata
    ``(num_pages, hkv, d)`` shards its page dim identically, keeping a
    page's rows and its min/max stats on the same chip).

    **Page-id remap**: with ``t`` shards, physical page ``p`` lives on
    shard ``p // (num_pages // t)`` at *local* page id
    ``p % (num_pages // t)`` (local row ``local_page * page_size + off``).
    The engine's page tables carry *global* ids — XLA lowers the pooled
    gathers/scatters to all-gathers over ``model`` automatically, and a
    future hand-written kernel must apply exactly this remap (plus a
    broadcast of the null page 0, which lands on shard 0) to go
    collective-free.  Per-slot state (recurrent mixers, cross-attn,
    per-slot ``ds_channels``) shards its batch dim over the fsdp axes, like
    the contiguous layout.
    """
    axes = MeshAxes.for_mesh(mesh)
    fsdp_size, t_size = axes.sizes(mesh)
    b_ax = axes.batch if _divisible(batch, fsdp_size) else None
    pool_ax = axes.tensor if _divisible(num_pages, t_size) else None

    def spec(path, leaf):
        ps = _path_str(path)
        name = ps.split("/")[-1]
        shape = leaf.shape
        stacked = ps.startswith("blocks/")
        inner = shape[1:] if stacked else shape

        def wrap(*s):
            return P(None, *s) if stacked else P(*s)

        if name in ("k", "v", "qk_packed", "qk_scale", "qk_zero"):
            return wrap(pool_ax, None, None)  # (rows, hkv, c)
        if name in ("pmax", "pmin"):
            return wrap(pool_ax, None, None)  # (num_pages, hkv, d)
        if name == "h2o_mass":
            # (num_pages, hkv) physical-page H2O mass: shards its page dim
            # with the pool (same remap as pmax/pmin) — never over batch.
            return wrap(pool_ax, None)
        if name == "ds_channels":
            return wrap(b_ax, None, None)  # (batch, hkv, r) per-slot
        if name in ("cross_k", "cross_v"):
            return wrap(b_ax, None, None, None)
        if name == "ssm":  # (b, d_inner, d_state)
            return wrap(b_ax, _tensor_if(axes, mesh, inner[1]), None)
        if name == "conv":  # (b, k-1, d_inner)
            return wrap(b_ax, None, _tensor_if(axes, mesh, inner[2]))
        if name in ("C", "n", "m", "c", "h"):  # xLSTM states
            rest = [None] * (len(inner) - 1)
            return wrap(b_ax, *rest)
        rest = [None] * max(0, len(inner) - 1)
        return wrap(b_ax, *rest)

    return jax.tree_util.tree_map_with_path(spec, state)
