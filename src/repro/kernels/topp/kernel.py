"""Pallas kernel: top-p threshold via binary search (Algorithm 1).

Each grid step owns a block of weight rows resident in VMEM and runs the
fixed-trip binary search; the masked accumulation ``sum(where(w >= m, w, 0))``
is a fused VPU select+reduce over the whole row — the TPU analogue of the
paper's fused max/where/sum loop (no intermediate W0/W1/W2 materialized).

A 524288-float row is 2 MB, comfortably within VMEM; the wrapper drops to
one row per grid step for very long contexts and batches rows otherwise.
Output is the threshold ``l`` per row; the boolean mask ``w >= l`` is left
to the caller (XLA fuses it into the consumer — on TPU it feeds straight
into the sparse-attention kernel's mask operand).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import resolve_interpret


def _topp_kernel(w_ref, p_ref, thresh_ref, budget_ref, *, iters: int):
    w = w_ref[...].astype(jnp.float32)  # (block_r, n)
    p = p_ref[0]
    lo = jnp.zeros((w.shape[0],), jnp.float32)
    hi = jnp.max(w, axis=-1)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        kept = jnp.sum(jnp.where(w >= mid[:, None], w, 0.0), axis=-1)
        ok = kept >= p
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    thresh_ref[...] = lo[:, None]
    budget_ref[...] = jnp.sum((w >= lo[:, None]).astype(jnp.int32), axis=-1,
                              keepdims=True)


@functools.partial(jax.jit, static_argnames=("iters", "block_rows", "interpret"))
def topp_threshold_rows(
    weights: jax.Array,  # (rows, n) f32 normalized attention weights
    p: jax.Array,  # scalar f32
    *,
    iters: int = 24,
    block_rows: int = 8,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (threshold (rows, 1) f32, budget (rows, 1) i32)."""
    interpret = resolve_interpret(interpret)
    rows, n = weights.shape
    # Keep the block under ~4 MB of VMEM.
    max_rows = max(1, (4 << 20) // (4 * n))
    block_rows = min(block_rows, max_rows, rows)
    while rows % block_rows:
        block_rows -= 1
    p_arr = jnp.broadcast_to(jnp.asarray(p, jnp.float32), (1,))
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_topp_kernel, iters=iters),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, 1), jnp.float32),
            jax.ShapeDtypeStruct((rows, 1), jnp.int32),
        ],
        interpret=interpret,
    )(weights, p_arr)
