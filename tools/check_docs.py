#!/usr/bin/env python3
"""Docs link/reference checker — fails CI on rot.

Scans every tracked markdown file for

* relative markdown links ``[text](path)`` — the target must exist on
  disk (``#fragment`` suffixes and ``http(s)://``/``mailto:`` links are
  ignored);
* repo-file references inside code spans/blocks — any token shaped like
  ``src/…/file.py``, ``benchmarks/…``, ``examples/…``, ``docs/…``,
  ``tests/…``, ``tools/…``, or ``.github/…`` must exist, so command lines
  and layout listings in README/docs can't silently rot.

Usage: python tools/check_docs.py [file.md …]   (no args: all tracked .md)
"""

from __future__ import annotations

import os
import re
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# Fenced blocks and inline code spans: excluded from the *link* pass —
# `buf[_slice](arg=)` in prose about APIs is not a markdown link.  The
# repo-path pass below still scans them (that is its whole point).
CODE_RE = re.compile(r"```.*?```|`[^`\n]*`", re.S)
# Repo paths mentioned in prose/code blocks: a known top-level dir followed
# by a concrete file with an extension (directories get a trailing /).
PATH_RE = re.compile(
    r"(?<![\w/.-])((?:src|benchmarks|examples|docs|tests|tools|\.github)"
    r"/[\w./-]*[\w-]\.[\w]+|(?:src|benchmarks|examples|docs|tests|tools)"
    r"/[\w./-]*/)")
SKIP_SCHEMES = ("http://", "https://", "mailto:", "#")


def tracked_markdown() -> list[str]:
    out = subprocess.run(["git", "ls-files", "*.md", "**/*.md"],
                         cwd=ROOT, capture_output=True, text=True,
                         check=True).stdout
    return sorted(set(out.split()))


def check_file(relpath: str) -> list[str]:
    errors = []
    path = os.path.join(ROOT, relpath)
    with open(path, encoding="utf-8") as f:
        text = f.read()
    base = os.path.dirname(path)
    for m in LINK_RE.finditer(CODE_RE.sub("", text)):
        target = m.group(1)
        if target.startswith(SKIP_SCHEMES):
            continue
        target = target.split("#", 1)[0]
        if not target:
            continue
        if not os.path.exists(os.path.normpath(os.path.join(base, target))):
            errors.append(f"{relpath}: broken link -> {m.group(1)}")
    for m in PATH_RE.finditer(text):
        target = m.group(1)
        if not os.path.exists(os.path.join(ROOT, target)):
            errors.append(f"{relpath}: missing repo path -> {target}")
    return errors


def main() -> int:
    files = sys.argv[1:] or tracked_markdown()
    errors: list[str] = []
    for f in files:
        errors.extend(check_file(f))
    for e in errors:
        print(f"ERROR: {e}")
    print(f"checked {len(files)} markdown files: "
          f"{'FAIL' if errors else 'ok'}")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
