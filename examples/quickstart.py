"""Quickstart: the Twilight pipeline on raw arrays, end to end.

    PYTHONPATH=src python examples/quickstart.py

Shows the three stages (Token Selector -> Twilight Pruner -> sparse
attention), the adaptive budget, and the error bound — in ~40 lines of
public API.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    SelectionContext,
    TwilightConfig,
    attention_error,
    build_page_meta,
    full_decode_attention,
    twilight_decode_attention,
)

rng = np.random.default_rng(0)
b, hq, hkv, n, d = 2, 8, 2, 4096, 64

q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
K = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
V = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)

# Plant a few "needle" keys so attention is focused (the regime where
# top-p pruning shines).
Kn = np.array(K)
for i in range(b):
    for h in range(hkv):
        qm = np.asarray(q).reshape(b, hkv, hq // hkv, d)[i, h].mean(0)
        Kn[i, rng.integers(0, n, 3), h] = 3.0 * qm
K = jnp.asarray(Kn)

cfg = TwilightConfig(selector="quest", p=0.95, candidate_frac=0.25,
                     page_size=64)
ctx = SelectionContext(keys=K, page_meta=build_page_meta(K, 64),
                       accum_scores=None, length=None, ds_channels=None)

out = jax.jit(lambda q, K, V: twilight_decode_attention(
    q, K, V, cfg, ctx=ctx))(q, K, V)
exact = full_decode_attention(q, K, V)

err = float(attention_error(exact, out.out).max())
vf = float(jnp.linalg.norm(V[0, :, 0]))
print(f"context            : {n} tokens")
print(f"selector candidates: {np.asarray(out.stats.candidate_budget).mean():.0f}"
      f"  (B0 = n/4 = {cfg.candidate_budget(n)})")
print(f"top-p kept         : {np.asarray(out.stats.pruned_budget).mean():.0f}"
      f"  ({100 * (1 - out.stats.pruned_budget.mean() / n):.1f}% of context pruned)")
print(f"‖o - ô‖ / bound    : {err:.4f} / {(1 - cfg.p) * vf:.4f} "
      f"(Eq. 2: (1-p)·‖V‖_F)")
