"""Token sampling.  Pleasing symmetry: the same top-p machinery the paper
moved *into* attention is used here for its original purpose (nucleus
sampling of the output distribution), via the identical binary-search
threshold."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.topp import topp_mask


def top_p_sample(key: jax.Array, logits: jax.Array, p: float = 0.9,
                 temperature: float = 1.0) -> jax.Array:
    """Nucleus sampling.  logits: (b, vocab) -> (b,) i32."""
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    probs = jax.nn.softmax(logits.astype(jnp.float32) / temperature, axis=-1)
    kept = topp_mask(probs, p).mask
    masked = jnp.where(kept, logits, jnp.finfo(jnp.float32).min)
    return jax.random.categorical(key, masked.astype(jnp.float32), axis=-1
                                  ).astype(jnp.int32)


def sample_token(key: jax.Array, logits: jax.Array, *,
                 greedy: bool | jax.Array = False,
                 p: float = 0.9, temperature: float = 1.0) -> jax.Array:
    """Sample (b,) tokens from (b, vocab) logits.

    ``greedy`` is either a Python bool (whole batch) or a (b,) bool mask —
    the per-slot sampling mode the continuous-batching engine carries, so a
    greedy request and a nucleus-sampling request can share one batch step.
    """
    if isinstance(greedy, bool):
        if greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return top_p_sample(key, logits, p=p, temperature=temperature)
    argmax = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    sampled = top_p_sample(key, logits, p=p, temperature=temperature)
    return jnp.where(jnp.asarray(greedy), argmax, sampled)
