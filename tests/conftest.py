"""Shared test fixtures.  NOTE: no XLA_FLAGS here — tests run on the single
CPU device; only launch/dryrun.py requests 512 placeholder devices."""

import os
import sys

# Tests import helpers as `tests.conftest` and benchmarks as `benchmarks.*`;
# make the repo root importable regardless of how pytest was invoked.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

import zlib

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running tests (subprocess dry-run compiles)")


@pytest.fixture()
def rng(request):
    """Per-test deterministic random stream, independent of suite order.

    The old session-scoped generator advanced across tests, so the data any
    test saw depended on which tests ran before it — running a subset (or
    -x aborting early) changed inputs, which is how borderline-tolerance
    tests (the jamba teacher-forcing check) appeared to "flip".  Seeding
    from the test's node id gives every test its own fixed stream no matter
    what else runs.
    """
    return np.random.default_rng(zlib.crc32(request.node.nodeid.encode()))


def make_weights(rng, rows, n, concentration=3.0):
    """Random normalized attention-weight rows."""
    logits = rng.normal(size=(rows, n)) * concentration
    w = np.exp(logits - logits.max(-1, keepdims=True))
    return (w / w.sum(-1, keepdims=True)).astype(np.float32)
