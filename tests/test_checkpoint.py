"""Checkpoint round-trips (incl. bfloat16 and nested stacked trees)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_smoke_config
from repro.models import init_params


def test_roundtrip_simple(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray([1, 2], jnp.int32),
                  "d": jnp.ones((4,), jnp.bfloat16) * 1.5}}
    save_checkpoint(str(tmp_path), 7, tree)
    out = restore_checkpoint(str(tmp_path), 7, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_roundtrip_model_params(tmp_path):
    cfg = get_smoke_config("deepseek-moe-16b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 3, params)
    out = restore_checkpoint(str(tmp_path), 3, params)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_step(tmp_path):
    assert latest_step(str(tmp_path)) is None
    save_checkpoint(str(tmp_path), 1, {"x": jnp.ones(1)})
    save_checkpoint(str(tmp_path), 12, {"x": jnp.ones(1)})
    assert latest_step(str(tmp_path)) == 12


def test_shape_mismatch_rejected(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"x": jnp.ones((2,))})
    import pytest
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), 1, {"x": jnp.ones((3,))})
