"""Pure-jnp oracle for the INT4 quantization kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import quantize_int4


def quantize_int4_rows_ref(x: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    qt = quantize_int4(x)
    return qt.packed, qt.scale.astype(jnp.float32), qt.zero.astype(jnp.float32)
