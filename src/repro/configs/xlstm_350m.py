"""xLSTM-350M [arXiv:2405.04517] — sLSTM + mLSTM block stack (7:1), no FFN
(d_ff = 0; the blocks carry their own projections).

The Twilight technique is inapplicable (no attention weights / KV cache at
decode); the config keeps twilight.enabled=False and the model decodes via
its O(1) recurrent state (DESIGN.md §Arch-applicability).
"""

from repro.core.twilight import TwilightConfig
from repro.models.common import ArchType, ModelConfig, XLSTMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m",
        arch_type=ArchType.SSM,
        n_layers=24,
        d_model=1024,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        xlstm=XLSTMConfig(slstm_every=8, proj_factor=2.0, conv_kernel=4),
        twilight=TwilightConfig(enabled=False),
        citation="arXiv:2405.04517",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, vocab_size=512,
        xlstm=XLSTMConfig(slstm_every=2, proj_factor=2.0, conv_kernel=2),
    )
