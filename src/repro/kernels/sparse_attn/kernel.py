"""Pallas kernel: single-query sparse decode attention with page early-out.

TPU adaptation of the paper's head-wise varlen sparse attention kernel
(§4.2, Appendix B.2).  The GPU version gathers a per-head variable-length
token list (FlashInfer varlen scheduling); on TPU shapes must be static, so
the kernel consumes the *mask* produced by the top-p pruner and processes
the KV cache in fixed pages:

* online-softmax (flash-decoding) accumulation across page-grid steps,
* tokens outside the top-p set are masked to -inf,
* **page skip**: if an entire page is masked out (the common case — the
  pruner keeps ~2 % of tokens), the whole matmul+softmax update for that
  page is skipped behind a ``lax.cond``.  On TPU the page's K/V tiles are
  still streamed by the grid pipeline, but the MXU work is elided; the
  gather-based engine path (`ops.gathered_attention`) additionally avoids
  the traffic by compacting candidate pages first.

One query *group* (the GQA unit — budgets are group-wise, Appendix B.2)
per grid row; pages iterate on the minor grid axis with VMEM accumulators.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import NEG_INF, resolve_interpret


def _sparse_attn_kernel(q_ref, k_ref, v_ref, mask_ref, out_ref,
                        m_scr, l_scr, acc_scr, *, sm_scale: float):
    j = pl.program_id(1)
    nblocks = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    mask = mask_ref[0] != 0  # (block_n,)

    def _update():
        q = q_ref[0].astype(jnp.float32)  # (group, d)
        k = k_ref[0].astype(jnp.float32)  # (block_n, d)
        v = v_ref[0].astype(jnp.float32)  # (block_n, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
        s = jnp.where(mask[None, :], s, NEG_INF)  # (group, block_n)
        m_prev = m_scr[...]  # (group, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p_ij = jnp.exp(s - m_new)
        p_ij = jnp.where(mask[None, :], p_ij, 0.0)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p_ij, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p_ij, v, preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    # Page-granular early-out: skip fully-pruned pages entirely.
    jax.lax.cond(jnp.any(mask), _update, lambda: None)

    @pl.when(j == nblocks - 1)
    def _finalize():
        l = l_scr[...]
        out = acc_scr[...] / jnp.maximum(l, 1e-30)
        out_ref[0] = jnp.where(l > 0.0, out, 0.0).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("sm_scale", "block_n", "interpret"))
def sparse_decode_attention(
    q: jax.Array,  # (B, group, d) — B = batch * kv_heads
    keys: jax.Array,  # (B, n, d)
    values: jax.Array,  # (B, n, d)
    mask: jax.Array,  # (B, n) int8/bool — top-p kept set
    *,
    sm_scale: float,
    block_n: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    interpret = resolve_interpret(interpret)
    B, group, d = q.shape
    n = keys.shape[1]
    block_n = min(block_n, n)
    while n % block_n:
        block_n -= 1
    grid = (B, n // block_n)
    mask = mask.astype(jnp.int8)
    return pl.pallas_call(
        functools.partial(_sparse_attn_kernel, sm_scale=sm_scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, group, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, block_n, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_n, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, group, d), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, group, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),  # m — running max
            pltpu.VMEM((group, 1), jnp.float32),  # l — running denominator
            pltpu.VMEM((group, d), jnp.float32),  # acc — unnormalized output
        ],
        interpret=interpret,
    )(q, keys, values, mask)
