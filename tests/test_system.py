"""End-to-end behaviour tests for the paper's system.

Trains a tiny model for real, then validates the full Twilight pipeline on
it: sparse decode matches full attention within the paper's error bound,
top-p prunes adaptively, and the serving engine produces identical greedy
output with and without pruning at high p.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import TwilightConfig
from repro.data import DataConfig, synthetic_lm_batches
from repro.models import decode_step, forward, init_params, prefill
from repro.serving import DecodeEngine, Request
from repro.training import TrainConfig, train_loop


@pytest.fixture(scope="module")
def trained():
    cfg = get_smoke_config("qwen2-1.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8,
                      seed=0)
    tcfg = TrainConfig(peak_lr=3e-3, warmup_steps=5, total_steps=40,
                       remat=False)
    params, hist = train_loop(params, cfg, tcfg,
                              synthetic_lm_batches(dcfg, 40), log_every=39)
    return cfg, params, hist


def test_training_learned(trained):
    _, _, hist = trained
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.3


def _decode_logits(params, cfg, toks, n_steps=8):
    _, state = prefill(params, cfg, {"tokens": toks[:, :32]}, n_max=64)
    out = []
    for t in range(32, 32 + n_steps):
        lg, state, stats = decode_step(params, cfg, state, toks[:, t])
        out.append(np.asarray(lg, np.float32))
    return np.stack(out, 1), stats


def test_twilight_decode_close_to_full(trained):
    cfg, params, _ = trained
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 48)))

    cfg_full = cfg.replace(twilight=TwilightConfig(enabled=False))
    cfg_twi = cfg.replace(twilight=dataclasses.replace(
        cfg.twilight, p=0.98, candidate_frac=1.0, selector="full"))
    full_lg, _ = _decode_logits(params, cfg_full, toks)
    twi_lg, stats = _decode_logits(params, cfg_twi, toks)
    # Argmax agreement on a trained model at p=0.98.
    agree = (full_lg.argmax(-1) == twi_lg.argmax(-1)).mean()
    assert agree >= 0.9, f"greedy agreement {agree}"
    # And the budget was actually pruned below the context length.
    assert float(stats["mean_pruned_budget"]) < 40


def test_budget_adapts_to_p(trained):
    cfg, params, _ = trained
    rng = np.random.default_rng(6)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 48)))
    budgets = []
    for p in (0.5, 0.9, 0.99):
        cfg_p = cfg.replace(twilight=dataclasses.replace(
            cfg.twilight, p=p, candidate_frac=1.0, selector="full"))
        _, stats = _decode_logits(params, cfg_p, toks, n_steps=2)
        budgets.append(float(stats["mean_pruned_budget"]))
    assert budgets == sorted(budgets), budgets


def test_engine_end_to_end_with_twilight(trained):
    cfg, params, _ = trained
    rng = np.random.default_rng(7)
    engine = DecodeEngine(cfg, params=params, batch_size=2,
                          cache_capacity=64)
    reqs = [Request(uid=i, prompt=rng.integers(
        8, cfg.vocab_size, 24).astype(np.int32), max_new_tokens=5)
        for i in range(2)]
    results = engine.generate(reqs)
    assert all(len(r.tokens) == 5 for r in results)
    assert all(r.mean_pruned_budget > 0 for r in results)
