"""StarCoder2-15B [arXiv:2402.19173] — dense GQA kv=4, RoPE."""

from repro.core.twilight import TwilightConfig
from repro.models.common import ArchType, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b",
        arch_type=ArchType.DENSE,
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=4,
        d_ff=24576,
        vocab_size=49152,
        rope_theta=1e5,
        twilight=TwilightConfig(selector="quest", p=0.95),
        citation="arXiv:2402.19173",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=192, n_heads=6, n_kv_heads=2, d_ff=384,
        vocab_size=512,
        twilight=TwilightConfig(selector="quest", p=0.9, page_size=8,
                                min_candidate=16),
    )
