"""Qwen3-32B [hf:Qwen/Qwen3-8B family] — dense GQA kv=8 with qk-norm,
explicit head_dim=128."""

from repro.core.twilight import TwilightConfig
from repro.models.common import ArchType, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b",
        arch_type=ArchType.DENSE,
        n_layers=64,
        d_model=5120,
        n_heads=64,
        n_kv_heads=8,
        d_head=128,
        d_ff=25600,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1e6,
        twilight=TwilightConfig(selector="quest", p=0.95),
        citation="hf:Qwen/Qwen3-8B",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
        d_ff=256, vocab_size=512,
        twilight=TwilightConfig(selector="quest", p=0.9, page_size=8,
                                min_candidate=16),
    )
