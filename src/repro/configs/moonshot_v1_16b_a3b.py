"""Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B] — DeepSeek-MoE-family
fine-grained MoE (64 routed top-6 + 2 shared).

Pool tag says [dense] but the config line specifies "MoE 64e top-6"; the
released Moonlight model is MoE, so we implement MoE and record the tag
inconsistency in DESIGN.md §Arch-applicability.
"""

from repro.core.twilight import TwilightConfig
from repro.models.common import ArchType, MoEConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b",
        arch_type=ArchType.MOE,
        n_layers=48,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab_size=163840,
        moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_expert=1408,
                      period=1),
        twilight=TwilightConfig(selector="double_sparsity", p=0.95),
        citation="hf:moonshotai/Moonlight-16B-A3B",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=64,
        vocab_size=512,
        moe=MoEConfig(n_experts=4, top_k=2, n_shared=1, d_expert=64, period=1),
        twilight=TwilightConfig(selector="double_sparsity", p=0.9, page_size=8,
                                min_candidate=16),
    )
