"""The Twilight Pruner (§4.1–4.2): re-estimate attention weights on the
candidate set with an INT4-quantized K cache, then keep only the top-p subset.

GQA semantics (Appendix B.2): weights and top-p masks are computed per *query*
head; the pruned set actually loaded for a KV head is the union over its
group, so budgets are group-wise under GQA and head-wise under MHA.

Three entry points:

* :meth:`TwilightPruner.prune` — dense/debug path over (b, hkv, n) masks;
  estimates q·K̃ against the *whole* cache.  The test oracle.
* :meth:`TwilightPruner.prune_at` — compact staged path over a selector
  index buffer (b, hkv, m): gathers the INT4 shadow codes at the candidate
  indices and runs estimate + top-p on m-length rows, so per-step cost
  scales with the candidate budget B0, not the context length n.
* :meth:`TwilightPruner.prune_attend_at` — the fused production path: the
  whole estimate → top-p → sparse-attention tail as ONE Pallas launch
  (``kernels/fused_decode``); the estimate always runs from the packed
  INT4 codes (``estimate_bits <= 4`` configs only — the config resolver
  routes others to the staged path).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import quant as quant_lib
from repro.core import topp as topp_lib
from repro.core.attention import gather_kv_heads, gather_quantized_kv_heads
from repro.core.selectors import group_union

__all__ = ["PrunerStats", "TwilightPruner"]


class PrunerStats(NamedTuple):
    candidate_budget: jax.Array  # i32 (b, hkv) — |I0| per group
    pruned_budget: jax.Array  # i32 (b, hkv) — |I1| per group after top-p
    threshold: jax.Array  # f32 (b, hq) — applied weight threshold
    # f32 (b, hq, n) estimated normalized weights.  Dense/debug path only —
    # the compact path never materializes an n-length buffer, so the jitted
    # decode step carries None here.
    weights: jax.Array | None = None


@dataclasses.dataclass(frozen=True)
class TwilightPruner:
    """Top-p pruning over selector candidates.

    Args:
      p: cumulative-weight threshold (paper uses 0.95 LLaMA, 0.85 Longchat).
      iters: binary-search iterations (Algorithm 1).
      estimate_bits: 4 (paper sweet spot), 8, or 16 (= no quantization) for
        the score-estimation K cache.  Fig. 6 ablation is reproduced by
        sweeping this.
    """

    p: float = 0.95
    iters: int = 24
    estimate_bits: int = 4
    # Route the compact estimate through the spgemv Pallas kernel (INT4
    # dequant folded into the matmul epilogue).  The jnp gather+einsum path
    # below stays as the reference/oracle; TwilightConfig.estimate_backend
    # resolves this flag ("auto" -> TPU only).
    use_spgemv: bool = False

    def estimate_scores(
        self,
        q: jax.Array,  # (b, hq, d)
        keys: jax.Array | None,  # (b, n, hkv, d) fp K (estimate_bits >= 16)
        qkeys: quant_lib.QuantizedTensor | None,  # INT4 shadow cache
    ) -> jax.Array:
        """q·K̃ / sqrt(d) per query head: (b, hq, n)."""
        if self.estimate_bits <= 4:
            if qkeys is None:
                if keys is None:
                    raise ValueError("need keys or qkeys")
                qkeys = quant_lib.quantize_int4(keys)
            # bf16 is exact enough for 4-bit codes and halves the
            # materialized estimate buffer (the Pallas spgemv kernel never
            # materializes it at all — this is the jnp fallback).
            k_est = quant_lib.dequantize_int4(qkeys, dtype=jnp.bfloat16)
        else:
            if keys is None:
                raise ValueError("need full-precision keys")
            k_est = keys
        b, n, hkv, d = k_est.shape
        hq = q.shape[1]
        group = hq // hkv
        qg = q.reshape(b, hkv, group, d).astype(k_est.dtype)
        scores = jnp.einsum("bhgd,bnhd->bhgn", qg, k_est,
                            preferred_element_type=jnp.float32)
        return scores.reshape(b, hq, n) / jnp.sqrt(jnp.asarray(d, jnp.float32))

    def estimate_scores_at(
        self,
        q: jax.Array,  # (b, hq, d)
        indices: jax.Array,  # (b, hkv, m) i32 candidate positions
        keys: jax.Array | None = None,  # (b, n, hkv, d) fp K
        qkeys: quant_lib.QuantizedTensor | None = None,  # INT4 shadow cache
        valid: jax.Array | None = None,  # (b, hkv, m) live candidate slots
    ) -> jax.Array:
        """q·K̃ / sqrt(d) on the gathered candidate buffer: (b, hkv, g, m).

        Only m rows of the shadow cache are touched (d/2+8 bytes each) — the
        compact analogue of :meth:`estimate_scores`.

        ``valid`` (optional) marks the live candidate slots.  With the
        hierarchical page nucleus, whole nucleus-pruned pages of slots are
        dead; the spgemv kernel then early-outs those blocks so the
        estimate's compute scales with the surviving count, not the static
        buffer capacity.  Dead-slot scores are *unspecified* when ``valid``
        is passed — every consumer masks on ``valid`` before the softmax.
        """
        b, hkv, m = indices.shape
        hq = q.shape[1]
        group = hq // hkv
        if self.estimate_bits <= 4:
            # Gather-then-quantize is bit-identical to gathering a
            # quantized cache (per-row quantization) — and keeps this O(B0).
            gathered = gather_quantized_kv_heads(indices, keys=keys,
                                                 qkeys=qkeys)
            if self.use_spgemv:
                from repro.kernels.spgemv.ops import estimate_scores_gathered
                return estimate_scores_gathered(q, gathered, valid)
            k_est = quant_lib.dequantize_int4(gathered, dtype=jnp.bfloat16)
        else:
            if keys is None:
                raise ValueError("need full-precision keys")
            k_est = gather_kv_heads(keys, indices)
        d = k_est.shape[-1]
        qg = q.reshape(b, hkv, group, d).astype(k_est.dtype)
        scores = jnp.einsum("bhgd,bhmd->bhgm", qg, k_est,
                            preferred_element_type=jnp.float32)
        return scores / jnp.sqrt(jnp.asarray(d, jnp.float32))

    def prune_at(
        self,
        q: jax.Array,  # (b, hq, d)
        indices: jax.Array,  # (b, hkv, m) i32 from select_indices
        valid: jax.Array,  # (b, hkv, m) bool — live candidate slots
        *,
        keys: jax.Array | None = None,
        qkeys: quant_lib.QuantizedTensor | None = None,
        p: jax.Array | float | None = None,
    ) -> tuple[jax.Array, PrunerStats, jax.Array]:
        """Compact top-p prune: (kept (b, hkv, m) bool, stats, slot_weights).

        ``kept`` marks the surviving *slots* of the index buffer (GQA group
        union), i.e. the final set is ``indices[kept]``.  Equivalent to
        :meth:`prune` on the scattered mask, but every buffer is m-length.
        With a paged cache, ``indices`` are *physical* pool rows (already
        translated through the page table) and ``keys``/``qkeys`` carry the
        pool layout — the gathers dispatch on rank.
        ``slot_weights`` (b, hkv, m) f32 is the group-max estimated weight
        per slot — the ranking key for the optional B1 re-compaction before
        the final attention gather, and (masked to the kept slots) the
        per-step increment the serving engine scatter-adds into its
        page-granular H2O mass accumulator.
        """
        b, hkv, m = indices.shape
        hq = q.shape[1]
        p_val = self.p if p is None else p

        scores = self.estimate_scores_at(q, indices, keys, qkeys,
                                         valid=valid)  # (b,hkv,g,m)
        valid_g = jnp.broadcast_to(valid[:, :, None, :], scores.shape)
        weights = topp_lib.masked_softmax(scores, valid_g)
        res = topp_lib.topp_mask(weights, p_val, iters=self.iters)
        kept_q = res.mask & valid_g  # (b, hkv, g, m)
        kept = kept_q.any(axis=2)  # group union at slot granularity
        stats = PrunerStats(
            candidate_budget=valid.sum(-1).astype(jnp.int32),
            pruned_budget=kept.sum(-1).astype(jnp.int32),
            threshold=res.threshold.reshape(b, hq),
            weights=None,
        )
        return kept, stats, weights.max(axis=2)

    def prune_attend_at(
        self,
        q: jax.Array,  # (b, hq, d)
        indices: jax.Array,  # (b, hkv, m) i32 from select_indices
        valid: jax.Array,  # (b, hkv, m) bool — live candidate slots
        *,
        keys: jax.Array,  # (b, n, hkv, d) cache or (P, hkv, d) pool
        values: jax.Array,  # same layout as keys
        qkeys: quant_lib.QuantizedTensor | None = None,
        p: jax.Array | float | None = None,
        page_size: int = 64,
        hierarchical: bool = False,
    ) -> tuple[jax.Array, jax.Array, PrunerStats, jax.Array]:
        """Fused prune **and** attend: one Pallas launch for the whole
        estimate → top-p → sparse-attention tail of the pipeline
        (``kernels/fused_decode``).

        Returns ``(out (b, hq, d), kept (b, hkv, m), stats, slot_weights)``
        — the same pieces :meth:`prune_at` plus the final gather + attention
        produce, but with no HBM materialization of scores, thresholds, or
        a re-compacted index buffer, and with only *surviving* K/V rows read
        from the cache.  Every kept slot is attended (equivalent to the
        staged path with ``pruned_cap_frac=None``).  As in :meth:`prune_at`,
        ``indices`` are final cache coordinates (physical pool rows for a
        paged cache); ``page_size`` sets the kernel's block-run coalescing
        granularity (must match the pool's physical page size).
        ``hierarchical`` marks the candidate buffer as carrying an adaptive
        page-nucleus survivor set — the kernel's estimate stage then
        early-outs whole dead pages instead of scoring the full capacity.
        """
        from repro.kernels.fused_decode.ops import fused_prune_attend

        p_val = self.p if p is None else p
        out, kept, slot_weights, thresh = fused_prune_attend(
            q, indices, valid, keys, values, qkeys, p=p_val,
            iters=self.iters, page_size=page_size,
            hierarchical=hierarchical)
        stats = PrunerStats(
            candidate_budget=valid.sum(-1).astype(jnp.int32),
            pruned_budget=kept.sum(-1).astype(jnp.int32),
            threshold=thresh,
            weights=None,
        )
        return out, kept, stats, slot_weights

    def prune_attend_window_at(
        self,
        q: jax.Array,  # (b, kw, hq, d) — kw queued window positions
        indices: jax.Array,  # (b, hkv, m) i32 — shared candidate buffer
        valid: jax.Array,  # (b, kw, hkv, m) bool — per-position validity
        *,
        keys: jax.Array,
        values: jax.Array,
        qkeys: quant_lib.QuantizedTensor | None = None,
        p: jax.Array | float | None = None,
        page_size: int = 64,
        hierarchical: bool = False,
    ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
        """Multi-token fused prune + attend: ONE launch per layer decodes
        all kw window positions against one shared candidate buffer
        (selection anchored once; per-position causal validity in
        ``valid``).  The kernel streams the window *union* of survivor
        sets from HBM once.

        Returns per-position raw pieces ``(out (b, kw, hq, d), kept
        (b, kw, hkv, m), slot_weights (b, kw, hkv, m), threshold
        (b, kw, hq))`` — the caller assembles :class:`PrunerStats` for its
        anchor position (the pruner does not know which position anchors
        the window).
        """
        from repro.kernels.fused_decode.ops import fused_prune_attend_window

        p_val = self.p if p is None else p
        return fused_prune_attend_window(
            q, indices, valid, keys, values, qkeys, p=p_val,
            iters=self.iters, page_size=page_size,
            hierarchical=hierarchical)

    def prune_window_at(
        self,
        q: jax.Array,  # (b, kw, hq, d) — kw queued window positions
        indices: jax.Array,  # (b, hkv, m) i32 — shared candidate buffer
        valid: jax.Array,  # (b, kw, hkv, m) bool — per-position validity
        *,
        keys: jax.Array | None = None,
        qkeys: quant_lib.QuantizedTensor | None = None,
        p: jax.Array | float | None = None,
    ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Staged multi-token prune: ONE folded estimate over the shared
        candidate buffer (query rows laid out kv-head-major, position ×
        group inside each head — the same layout the fused kernel uses),
        then an independent per-position/per-head top-p.

        Returns ``(kept (b, kw, hkv, m), threshold (b, kw, hq),
        slot_weights (b, kw, hkv, m))``.  Each position's slice is exactly
        what :meth:`prune_at` would produce for that position alone.
        """
        b, kw, hq, d = q.shape
        hkv, m = indices.shape[1], indices.shape[2]
        group = hq // hkv
        p_val = self.p if p is None else p

        q2 = q.reshape(b, kw, hkv, group, d).transpose(0, 2, 1, 3, 4)
        q2 = q2.reshape(b, hkv * kw * group, d)
        # A slot is live for the folded estimate if any window position sees
        # it — the window union, matching the fused kernel's DMA set.
        scores = self.estimate_scores_at(q2, indices, keys, qkeys,
                                         valid=valid.any(axis=1))
        scores = scores.reshape(b, hkv, kw, group, m)
        valid_g = jnp.broadcast_to(
            valid.transpose(0, 2, 1, 3)[:, :, :, None, :], scores.shape)
        weights = topp_lib.masked_softmax(scores, valid_g)
        res = topp_lib.topp_mask(weights, p_val, iters=self.iters)
        kept_q = res.mask & valid_g  # (b, hkv, kw, group, m)
        kept = kept_q.any(axis=3).transpose(0, 2, 1, 3)  # (b, kw, hkv, m)
        slot_w = weights.max(axis=3).transpose(0, 2, 1, 3)
        thresh = res.threshold.transpose(0, 2, 1, 3).reshape(b, kw, hq)
        return kept, thresh, slot_w

    def prune(
        self,
        q: jax.Array,  # (b, hq, d)
        candidate_mask: jax.Array,  # (b, hkv, n) from the Token Selector
        *,
        keys: jax.Array | None = None,
        qkeys: quant_lib.QuantizedTensor | None = None,
        p: jax.Array | float | None = None,
    ) -> tuple[jax.Array, PrunerStats]:
        """Returns the pruned KV-head mask (b, hkv, n) and stats."""
        b, hkv, n = candidate_mask.shape
        hq = q.shape[1]
        group = hq // hkv
        p_val = self.p if p is None else p

        scores = self.estimate_scores(q, keys, qkeys)  # (b, hq, n)
        cand_q = jnp.repeat(candidate_mask, group, axis=1)  # (b, hq, n)
        weights = topp_lib.masked_softmax(scores, cand_q)  # normalized (C1: needs softmax)
        res = topp_lib.topp_mask(weights, p_val, iters=self.iters)
        pruned_q = res.mask & cand_q
        pruned_kv = group_union(pruned_q, hkv)  # (b, hkv, n)
        stats = PrunerStats(
            candidate_budget=candidate_mask.sum(-1).astype(jnp.int32),
            pruned_budget=pruned_kv.sum(-1).astype(jnp.int32),
            threshold=res.threshold,
            weights=weights,
        )
        return pruned_kv, stats
