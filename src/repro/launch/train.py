"""Training launcher.

Production mode lowers the pjit'd train step on the 16x16 (or 2x16x16) mesh;
on this CPU container use ``--smoke`` to actually execute a reduced config:

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 50 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data import DataConfig, batch_for_arch
from repro.models import count_params, init_params
from repro.training import TrainConfig, train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config, runs for real on CPU")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    print(f"[train] {cfg.name}: {count_params(params):,} params "
          f"({'smoke' if args.smoke else 'full'})")

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, seed=args.seed)
    rng = np.random.default_rng(args.seed)

    def batches():
        for _ in range(args.steps):
            yield batch_for_arch(cfg, dcfg, rng)

    tcfg = TrainConfig(peak_lr=args.lr, warmup_steps=max(1, args.steps // 10),
                       total_steps=args.steps, remat=not args.smoke)
    params, history = train_loop(params, cfg, tcfg, batches())
    if args.ckpt_dir:
        from repro.checkpoint import save_checkpoint
        path = save_checkpoint(args.ckpt_dir, args.steps, params)
        print(f"[train] checkpoint -> {path}")
    print(f"[train] final loss {history[-1]['loss']:.4f} "
          f"(start {history[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
