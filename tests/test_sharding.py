"""Sharding rules: spec validity for every (arch × shape) without compiling,
plus one real lower+compile smoke in a subprocess with placeholder devices."""

import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch.specs import (INPUT_SHAPES, decode_state_struct,
                                paged_decode_state_struct, paged_pool_pages,
                                params_struct)
from repro.sharding import (decode_state_specs, paged_decode_state_specs,
                            param_specs)


class _FakeMesh:
    """Duck-typed mesh: shape mapping + axis names (no devices needed)."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)

    @property
    def devices(self):  # pragma: no cover
        raise RuntimeError("spec-only mesh")


def _check_tree(struct, specs, mesh_shape):
    leaves_a = jax.tree_util.tree_leaves(struct)
    leaves_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves_a) == len(leaves_s)
    for arr, spec in zip(leaves_a, leaves_s):
        assert isinstance(spec, P)
        assert len(spec) <= len(arr.shape), (arr.shape, spec)
        for dim, ax in zip(arr.shape, spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= mesh_shape[a]
            assert dim % size == 0, (arr.shape, spec, ax)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divisible(arch):
    cfg = get_config(arch)
    mesh = _FakeMesh({"data": 16, "model": 16})
    struct = params_struct(cfg)
    specs = param_specs(struct, cfg, mesh)
    _check_tree(struct, specs, mesh.shape)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_decode_state_specs_divisible(arch, shape_name):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = _FakeMesh({"data": 16, "model": 16})
    struct = decode_state_struct(cfg, shape)
    specs = decode_state_specs(struct, cfg, mesh, batch=shape.global_batch,
                               capacity=shape.seq_len)
    _check_tree(struct, specs, mesh.shape)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_paged_decode_state_specs_divisible(arch):
    """Shared-pool serving state: the (num_pages*page_size, hkv, d) pools
    shard whole pages over `model` (page-id remap documented in
    sharding.rules.paged_decode_state_specs); per-slot state shards over
    the batch axes."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES["decode_paged_32k"]
    mesh = _FakeMesh({"data": 16, "model": 16})
    struct = paged_decode_state_struct(cfg, shape)
    num_pages = paged_pool_pages(cfg, shape)
    assert num_pages % 16 == 0, "pool page dim must divide the model axis"
    specs = paged_decode_state_specs(struct, cfg, mesh,
                                     batch=shape.global_batch,
                                     num_pages=num_pages)
    _check_tree(struct, specs, mesh.shape)
    # The pool actually shards: every attention layer's K pool carries the
    # model axis on its token-row dim (xLSTM has no attention layers — and
    # no pool to shard).
    flat = jax.tree_util.tree_leaves_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))
    k_specs = [s for path, s in flat if "'k'" in str(path[-1])]
    if cfg.xlstm is None:
        assert k_specs and all(s[1] == "model" for s in k_specs)


def test_paged_h2o_mass_shards_with_pool():
    """The H2O mass accumulator is physical-page keyed: it must shard its
    page dim with the pool (same remap as Quest metadata), never over the
    batch axes — in both the pooled and contiguous layouts."""
    import dataclasses
    cfg = get_config("qwen2-1.5b")
    cfg = cfg.replace(twilight=dataclasses.replace(cfg.twilight,
                                                   selector="h2o"))
    shape = INPUT_SHAPES["decode_paged_32k"]
    mesh = _FakeMesh({"data": 16, "model": 16})
    struct = paged_decode_state_struct(cfg, shape)
    num_pages = paged_pool_pages(cfg, shape)
    specs = paged_decode_state_specs(struct, cfg, mesh,
                                     batch=shape.global_batch,
                                     num_pages=num_pages)
    _check_tree(struct, specs, mesh.shape)
    flat = jax.tree_util.tree_leaves_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))
    mass = [s for path, s in flat if "h2o_mass" in str(path[-1])]
    assert mass and all(s[1] == "model" for s in mass)
    # Contiguous layout: (b, n_pages, hkv) — batch over fsdp, pages with
    # the kv-seq axis when divisible.
    cshape = INPUT_SHAPES["decode_32k"]
    cstruct = decode_state_struct(cfg, cshape)
    cspecs = decode_state_specs(cstruct, cfg, mesh,
                                batch=cshape.global_batch,
                                capacity=cshape.seq_len)
    _check_tree(cstruct, cspecs, mesh.shape)


def test_multipod_param_specs_divisible():
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    for arch in ("jamba-1.5-large-398b", "qwen2-1.5b", "internvl2-1b"):
        cfg = get_config(arch)
        struct = params_struct(cfg)
        specs = param_specs(struct, cfg, mesh)
        _check_tree(struct, specs, mesh.shape)


@pytest.mark.slow
def test_dryrun_cell_compiles_subprocess():
    """One real lower+compile on 512 placeholder devices (the dry-run path).
    Subprocess so the XLA device-count flag never leaks into this session.

    Skips cleanly on hosts without 512 devices (CI containers): the
    placeholder-device compile needs the real multi-host topology to be
    representative and reliably exceeds container memory/time budgets.
    Set REPRO_FORCE_DRYRUN_TEST=1 to run it anyway.
    """
    import os
    if (jax.device_count() < 512
            and not os.environ.get("REPRO_FORCE_DRYRUN_TEST")):
        pytest.skip("host lacks 512 devices; set REPRO_FORCE_DRYRUN_TEST=1 "
                    "to force the placeholder-device compile")
    code = (
        "from repro.launch.dryrun import run_cell;"
        "r = run_cell('qwen2-1.5b', 'decode_32k', False, verbose=False);"
        "assert 'error' not in r, r;"
        "assert r['flops'] > 0"
    )
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=420, env=env, cwd=".")
    assert out.returncode == 0, out.stderr[-2000:]
