"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Accuracy tables run for real
on tiny models trained in this container (cached under results/bench_cache);
efficiency tables use the TPU-v5e HBM-traffic cost model (decode attention
is memory-bound — the paper's premise); Algorithm-1 rows are wall-clock.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run fig7 tab2  # subset
"""

from __future__ import annotations

import sys
import time
import traceback

from benchmarks import accuracy, latency

TABLES = {
    "fig2": accuracy.fig2_budget_vs_ppl,  # budget-vs-ppl per algorithm
    "tab2": accuracy.tab2_longbench_proxy,  # Longbench-style retrieval
    "tab3": accuracy.tab3_ruler_proxy,  # RULER-style multi-needle
    "tab4": accuracy.tab4_medium_context,  # medium-context PPL
    "fig6": accuracy.fig6_quant_bits,  # estimate-precision ablation
    "tabD": accuracy.tabD_token_dropping,  # Appendix D: dropping vs selecting
    "fig9": accuracy.fig9_p_sensitivity,  # p sweep
    "fig7": latency.fig7_attention_speedup,  # operator speedups
    "fig8": latency.fig8_e2e_tpot,  # end-to-end TPOT
    "fig10": latency.fig10_time_breakdown,  # select/prune/attend split
    "tabE": latency.tabE_offload,  # offloading scenario
    "mixed": latency.serve_mixed_workload,  # continuous vs wave batching
    "shared_prefix": latency.serve_shared_prefix_workload,  # COW prefix cache
    "persistent": latency.serve_persistent_workload,  # session vs per-call
    "alg1": latency.alg1_topp_microbench,  # top-p binary search wall-clock
    "kernels": latency.kernels_interpret_sanity,  # Pallas interpret sanity
}


def main() -> None:
    names = sys.argv[1:] or list(TABLES)
    print("name,us_per_call,derived")
    failures = []
    for name in names:
        fn = TABLES[name]
        t0 = time.time()
        try:
            fn()
            print(f"# {name} done in {time.time() - t0:.1f}s")
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
            print(f"# {name} FAILED: {e}")
    # Roofline summary appended when the dry-run results exist.
    try:
        from benchmarks import roofline
        rows = roofline.full_table()
        for r in rows:
            csv = (f"roofline_{r['arch']}_{r['shape']},0.00,"
                   f"compute={r['compute_s']:.3e};memory={r['memory_s']:.3e};"
                   f"collective={r['collective_s']:.3e};"
                   f"dominant={r['dominant']};useful={r['useful_ratio']:.2f}")
            print(csv)
        print("# roofline done")
    except Exception as e:  # noqa: BLE001
        print(f"# roofline skipped: {e}")
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")


if __name__ == "__main__":
    main()
