"""SeamlessM4T-medium [arXiv:2308.11596] — encoder-decoder over audio frame
embeddings (speech encoder stubbed; `input_specs()` supplies frame
embeddings of shape (b, s_enc, d_model))."""

from repro.core.twilight import TwilightConfig
from repro.models.common import ArchType, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium",
        arch_type=ArchType.AUDIO,
        n_layers=12,  # decoder layers (pool spec); encoder adds 12 more
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=4096,
        vocab_size=256206,
        encoder_layers=12,
        frontend="audio",
        twilight=TwilightConfig(selector="quest", p=0.95),
        citation="arXiv:2308.11596",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab_size=512, encoder_layers=2,
        twilight=TwilightConfig(selector="quest", p=0.9, page_size=8,
                                min_candidate=16),
    )
