"""Serving engine + sampler."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.serving import DecodeEngine, Request, top_p_sample


def test_top_p_sample_restricts_support(rng):
    logits = jnp.asarray([[10.0, 9.5, 0.0, -5.0, -5.0]] * 64)
    keys = jax.random.split(jax.random.PRNGKey(0), 64)
    toks = np.asarray(jax.vmap(
        lambda k, l: top_p_sample(k, l[None], p=0.8)[0])(keys, logits))
    assert set(toks.tolist()) <= {0, 1}, "p=0.8 keeps only the two top tokens"


def test_greedy_sample():
    from repro.serving.sampler import sample_token
    logits = jnp.asarray([[0.0, 3.0, 1.0]])
    tok = sample_token(jax.random.PRNGKey(0), logits, greedy=True)
    assert int(tok[0]) == 1


def test_engine_generates(rng):
    cfg = get_smoke_config("qwen2-1.5b")
    engine = DecodeEngine(cfg, batch_size=2, cache_capacity=64)
    reqs = [Request(uid=i,
                    prompt=rng.integers(8, cfg.vocab_size, 24).astype(np.int32),
                    max_new_tokens=4) for i in range(3)]
    results = engine.generate(reqs)
    assert len(results) == 3
    for r in results:
        assert len(r.tokens) == 4
        assert all(0 <= t < cfg.vocab_size for t in r.tokens)
        assert r.mean_pruned_budget > 0


def test_engine_greedy_deterministic(rng):
    cfg = get_smoke_config("qwen2-1.5b")
    engine = DecodeEngine(cfg, batch_size=2, cache_capacity=64, seed=7)
    prompt = rng.integers(8, cfg.vocab_size, 24).astype(np.int32)
    r1 = engine.generate([Request(uid=0, prompt=prompt, max_new_tokens=6)])
    r2 = engine.generate([Request(uid=1, prompt=prompt, max_new_tokens=6)])
    assert r1[0].tokens == r2[0].tokens


def test_wave_clips_prompts_by_own_budget(rng):
    """A long-prompt/short-generation request batched behind a
    long-generation one keeps its own ``capacity - max_new`` prompt tokens:
    wave formation splits the incompatible pair instead of silently
    truncating (previously every prompt was clipped by the wave-wide
    max(max_new_tokens))."""
    cfg = get_smoke_config("qwen2-1.5b")
    long_prompt = rng.integers(8, cfg.vocab_size, 60).astype(np.int32)
    short_prompt = rng.integers(8, cfg.vocab_size, 10).astype(np.int32)
    reqs = [Request(uid=0, prompt=long_prompt, max_new_tokens=4),
            Request(uid=1, prompt=short_prompt, max_new_tokens=40)]
    eng = DecodeEngine(cfg, batch_size=2, cache_capacity=64, seed=7)
    # Unit: the packer refuses the incompatible pair but batches compatible
    # ones (shared cache position needs max(kept prompt) + max(max_new)
    # <= capacity).
    wave, rest = eng._form_wave(list(reqs))
    assert [r.uid for r in wave] == [0] and [r.uid for r in rest] == [1]
    both = [Request(uid=0, prompt=long_prompt, max_new_tokens=4),
            Request(uid=1, prompt=long_prompt, max_new_tokens=4)]
    wave, rest = eng._form_wave(list(both))
    assert len(wave) == 2 and not rest
    # End-to-end: uid 0 must decode exactly as if served alone with its
    # full 60-token prompt (the old clip kept only 24 of them).
    got = {r.uid: r.tokens for r in eng.generate(reqs)}
    solo = DecodeEngine(cfg, params=eng.params, batch_size=1,
                        cache_capacity=64, seed=7)
    want = solo.generate([Request(uid=0, prompt=long_prompt,
                                  max_new_tokens=4)])[0].tokens
    assert got[0] == want


def test_wave_rejects_oversized_max_new(rng):
    """Wave mode raises the same clean error as the paged path instead of
    silently producing a zero-width prompt batch."""
    cfg = get_smoke_config("qwen2-1.5b")
    eng = DecodeEngine(cfg, batch_size=1, cache_capacity=64)
    req = Request(uid=0,
                  prompt=rng.integers(8, cfg.vocab_size, 10).astype(np.int32),
                  max_new_tokens=64)
    with pytest.raises(ValueError, match="cache_capacity"):
        eng.generate([req])


def test_paged_greedy_reset_on_retire(rng):
    """A greedy request admitted into a slot freed by a sampling request
    decodes greedily — the slot's sampling mode never leaks across
    occupants (reset on retire + set on admission)."""
    cfg = get_smoke_config("qwen2-1.5b")
    pa = rng.integers(8, cfg.vocab_size, 20).astype(np.int32)
    pb = rng.integers(8, cfg.vocab_size, 20).astype(np.int32)
    eng = DecodeEngine(cfg, batch_size=1, cache_capacity=64, seed=7,
                       paged=True)
    got = {r.uid: r.tokens for r in eng.generate([
        Request(uid=0, prompt=pa, max_new_tokens=3, greedy=False),
        Request(uid=1, prompt=pb, max_new_tokens=4, greedy=True)])}
    solo = DecodeEngine(cfg, params=eng.params, batch_size=1,
                        cache_capacity=64, seed=123, paged=True)
    want = solo.generate([Request(uid=1, prompt=pb, max_new_tokens=4,
                                  greedy=True)])[0].tokens
    assert got[1] == want


def test_engine_vlm(rng):
    cfg = get_smoke_config("internvl2-1b")
    engine = DecodeEngine(cfg, batch_size=2, cache_capacity=64)
    reqs = [Request(
        uid=0, prompt=rng.integers(8, cfg.vocab_size, 16).astype(np.int32),
        max_new_tokens=3,
        extras={"patches": rng.normal(
            size=(cfg.n_prefix_tokens, cfg.d_model)).astype(np.float32)})]
    results = engine.generate(reqs)
    assert len(results[0].tokens) == 3
