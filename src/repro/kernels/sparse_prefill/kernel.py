"""Pallas kernel: page-nucleus block-sparse flash prefill.

Decode went survivor-only in PRs 5-7; this kernel is the prefill-side
counterpart for the TTFT path.  Per ``(slot, kv-head, query-block)`` grid
step it flash-attends one ``q_block``-query tile against **only the kv
blocks its query block kept** — the page-level top-p survivor set is
computed upstream (``ops.prefill_page_survivors``: Quest min/max scores
max-reduced over the query block, ``page_nucleus_mask``, causal frontier
+ recent window forced) and arrives as a per-query-block ``(1, 1, nb)``
int8 operand, the prefill twin of the fused decode kernel's ``(1, nb)``
page-survivor mask.

Streaming reuses the fused decode kernel's machinery wholesale:

* kv blocks have static length ``blk = coalesce_block(page_size,
  page_size)`` (page_size halved to ``MAX_BLOCK_ROWS``), so a block never
  straddles a physical page boundary and ``n`` reshapes to ``(nb, blk)``
  with no remainder;
* each surviving block is **one coalesced blk-row async copy** per
  stream through two ping-ponged VMEM staging buffers — the copy of
  block j+1 overlaps block j's online-softmax update.  Unlike decode
  (where token-level pruning can hollow a block out), prefill prunes at
  page granularity, so a surviving block is always dense and the fused
  kernel's per-row sparse fallback is structurally unnecessary here;
* **pruned blocks are never read from HBM**, and the kv-block loop stops
  at the query block's causal frontier (a traced bound), so compute and
  traffic both scale with the survivor count.

Layout contract (see ``src/repro/kernels/README.md``):

* grid = (B, nqb) with B = batch * kv_heads; query rows are GQA-group-
  major inside the tile: row r = t * group + g is query t, group member g
  (so the whole group shares its query's survivor row, Appendix B.2).
* ``rows`` are *final* HBM start rows per kv block: physical pool rows
  (page_table translated in the wrapper) for chunked paged prefill,
  ``j * blk`` for the contiguous fallback.
* masking is finite (``NEG_INF``), kv rows at or beyond ``kv_len`` are
  zeroed before the matmul (a partially-filled boundary page DMAs stale
  pool rows), and fully-masked query rows emit exact zeros.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import NEG_INF, resolve_interpret
from repro.kernels.fused_decode.kernel import coalesce_block


def _sparse_prefill_kernel(
    q_ref,  # (1, 1, qr, d) — qr = q_block * group, group-major rows
    surv_ref,  # (1, 1, nb) int8 — this query block's kv-block survivors
    rows_ref,  # (1, nb) i32 — HBM start row of each kv block
    len_ref,  # (1, 1) i32 — resident prefix length (keys < kv_len live)
    off_ref,  # (1, 1) i32 — position of this slot's first query row
    k_hbm,  # ANY: (b, n, hkv, d) contiguous or (P, hkv, d) pooled
    v_hbm,  # ANY: same layout as k_hbm
    out_ref,  # (1, 1, qr, d)
    k_scr,  # VMEM (2, blk, 1, d) double-buffered block staging
    v_scr,  # VMEM (2, blk, 1, d)
    sem_k,  # DMA semaphores, one per buffer slot
    sem_v,
    *,
    sm_scale: float,
    hkv: int,
    group: int,
    q_block: int,
    blk: int,
    pooled: bool,
):
    i = pl.program_id(0)
    qb = pl.program_id(1)
    bi = i // hkv
    hi = i % hkv

    qf = q_ref[0, 0].astype(jnp.float32)  # (qr, d)
    surv = surv_ref[0, 0] != 0  # (nb,)
    rows = rows_ref[0]  # (nb,)
    kv_len = len_ref[0, 0]
    off = off_ref[0, 0]
    qr, d = qf.shape
    nb = surv.shape[0]

    # Query row r = t * group + g sits at absolute position
    # off + qb * q_block + t; the whole GQA group shares that position.
    row_iota = jax.lax.broadcasted_iota(jnp.int32, (qr, blk), 0)
    col_iota = jax.lax.broadcasted_iota(jnp.int32, (qr, blk), 1)
    qpos = off + qb * q_block + row_iota // group  # (qr, blk)

    # Causal frontier: kv blocks wholly past this tile's last query (or
    # past the resident prefix) can never participate — the loop bound is
    # traced, so trailing dead blocks cost neither DMA nor compute.
    frontier = jnp.minimum(kv_len, off + (qb + 1) * q_block)
    nb_live = jnp.minimum((frontier + blk - 1) // blk, nb)

    def src_rows(start):
        if pooled:
            return (k_hbm.at[pl.ds(start, blk), pl.ds(hi, 1)],
                    v_hbm.at[pl.ds(start, blk), pl.ds(hi, 1)])
        return (k_hbm.at[bi, pl.ds(start, blk), pl.ds(hi, 1)],
                v_hbm.at[bi, pl.ds(start, blk), pl.ds(hi, 1)])

    def dma_block(j, ok, start):
        # Start and wait share this predicate expression (a pure function
        # of j), so every started copy is waited exactly once.  Page-level
        # pruning keeps surviving blocks dense, so the copy is always the
        # single coalesced blk-row form.
        slot = j % 2

        @pl.when(ok & surv[j])
        def _():
            ks, vs = src_rows(rows[j])
            ck = pltpu.make_async_copy(ks, k_scr.at[slot], sem_k.at[slot])
            cv = pltpu.make_async_copy(vs, v_scr.at[slot], sem_v.at[slot])
            if start:
                ck.start()
                cv.start()
            else:
                ck.wait()
                cv.wait()

    def attend_block(j, carry):
        slot = j % 2
        dma_block(j, True, start=False)  # block j landed in buffer slot
        # Prefetch block j+1 into the other buffer before touching j's
        # data — the copy runs during this block's flash update.
        dma_block(jnp.minimum(j + 1, nb - 1), j + 1 < nb_live, start=True)

        kb = k_scr[slot, :, 0].astype(jnp.float32)  # (blk, d)
        vb = v_scr[slot, :, 0].astype(jnp.float32)
        # Rows at or beyond kv_len hold stale pool data (a partially
        # filled boundary page, or a dead block's untouched buffer) —
        # zero them so garbage can never reach the accumulator through
        # a 0*NaN product.
        live_row = (j * blk + jax.lax.broadcasted_iota(
            jnp.int32, (blk, d), 0)) < kv_len
        kb = jnp.where(live_row, kb, 0.0)
        vb = jnp.where(live_row, vb, 0.0)

        s = jnp.dot(qf, kb.T, preferred_element_type=jnp.float32) * sm_scale
        kpos = j * blk + col_iota
        mask = (kpos <= qpos) & (kpos < kv_len)
        s = jnp.where(mask, s, NEG_INF)  # finite mask — no inf-inf NaNs

        m_run, l_run, acc = carry
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_run - m_new)
        p_t = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        l_new = l_run * alpha + jnp.sum(p_t, axis=-1, keepdims=True)
        acc_new = acc * alpha + jnp.dot(p_t, vb,
                                        preferred_element_type=jnp.float32)
        new = (m_new, l_new, acc_new)
        # Dead blocks skip the carry entirely — the stale buffer's zeroed
        # rows are still masked, but the select makes it structural.
        return jax.tree_util.tree_map(
            lambda n, c: jnp.where(surv[j], n, c), new, carry)

    init = (jnp.full((qr, 1), NEG_INF, jnp.float32),
            jnp.zeros((qr, 1), jnp.float32),
            jnp.zeros((qr, d), jnp.float32))
    dma_block(0, nb_live > 0, start=True)  # warm the first buffer
    _, l_run, acc = jax.lax.fori_loop(0, nb_live, attend_block, init)
    out = acc / jnp.maximum(l_run, 1e-30)
    out = jnp.where(l_run > 0.0, out, 0.0)  # fully-masked rows emit zeros
    out_ref[0, 0] = out.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("sm_scale", "hkv", "group", "q_block", "pooled",
                     "page_size", "interpret"),
)
def sparse_prefill_rows(
    q: jax.Array,  # (B, nqb, qr, d) — B = batch * kv_heads
    survivors: jax.Array,  # (B, nqb, nb) bool/int8 kv-block survivors
    rows: jax.Array,  # (B, nb) i32 HBM start row per kv block
    kv_len: jax.Array,  # (B, 1) i32
    q_offset: jax.Array,  # (B, 1) i32
    keys: jax.Array,  # (b, n, hkv, d) or (P, hkv, d) — stays in HBM
    values: jax.Array,  # same layout as keys
    *,
    sm_scale: float,
    hkv: int,
    group: int,
    q_block: int,
    pooled: bool,
    page_size: int = 64,
    interpret: bool | None = None,
) -> jax.Array:
    """One launch per prefill (or prefill chunk): (B, nqb, qr, d) output.

    ``survivors`` is the per-query-block page-survivor operand at ``blk``
    granularity (``blk = coalesce_block(page_size, page_size)``); the
    wrapper expands page survivors to sub-blocks, exactly as the fused
    decode wrapper derives its ``(1, nb)`` mask from candidate validity.
    """
    interpret = resolve_interpret(interpret)
    B, nqb, qr, d = q.shape
    nb = survivors.shape[-1]
    blk = coalesce_block(page_size, page_size)
    survivors = survivors.astype(jnp.int8)
    return pl.pallas_call(
        functools.partial(_sparse_prefill_kernel, sm_scale=sm_scale,
                          hkv=hkv, group=group, q_block=q_block, blk=blk,
                          pooled=pooled),
        grid=(B, nqb),
        in_specs=[
            pl.BlockSpec((1, 1, qr, d), lambda i, qb: (i, qb, 0, 0)),
            pl.BlockSpec((1, 1, nb), lambda i, qb: (i, qb, 0)),
            pl.BlockSpec((1, nb), lambda i, qb: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, qb: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, qb: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),  # K cache/pool, HBM
            pl.BlockSpec(memory_space=pltpu.ANY),  # V cache/pool, HBM
        ],
        out_specs=pl.BlockSpec((1, 1, qr, d), lambda i, qb: (i, qb, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nqb, qr, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, blk, 1, d), keys.dtype),
            pltpu.VMEM((2, blk, 1, d), values.dtype),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(q, survivors, rows, kv_len.astype(jnp.int32),
      q_offset.astype(jnp.int32), keys, values)
