"""Pallas kernel: the whole Twilight prune-and-attend, fused into ONE launch.

The staged compact decode path runs three Pallas launches per attention
layer per decode step — spgemv INT4 estimate, top-p threshold search,
gathered sparse attention — and round-trips the B0-length score rows,
weight rows, kept masks, and the optional B1 re-compaction index buffer
through HBM between every stage.  This kernel is the paper's central
systems contribution (§4.2: run the hierarchical prune *inside* the
attention kernel): per (slot, kv-head) grid step it

1. stages the candidate rows' packed INT4 codes into VMEM and computes the
   estimated scores with the dequantization folded into the matmul
   epilogue (exactly the spgemv kernel's math — two nibble matmuls on the
   MXU plus a rank-1 VPU epilogue),
2. normalizes them with a masked softmax — the weight rows never leave
   VMEM,
3. runs the fixed-trip top-p binary search (Algorithm 1) on the resident
   rows, per query head *and per window position*, and unions the kept
   sets over the GQA group (per position) and over the window (the DMA
   set),
4. immediately performs the pruned sparse attention: the union kept
   bitmap is compacted into page-aligned *block runs* and the surviving
   blocks are streamed from the fp16 K/V cache (contiguous or shared page
   pool) through two ping-ponged VMEM staging buffers — the async copy of
   block run i+1 overlaps the flash-style online-softmax update of block
   run i.  **Pruned blocks are never read from HBM**; within a surviving
   block the kernel picks per block between one coalesced blk-row copy
   and per-row copies of just the kept rows, whichever moves fewer
   byte-equivalents (see ``DMA_OVERHEAD_BYTES``).

No scores, thresholds, or B1 index buffers are ever materialized in HBM;
the only O(m) outputs are the per-position kept bitmaps and group-max
slot weights, which the serving engine is required to see (H2O page-mass
maintenance).

Attention semantics match the staged pipeline with ``pruned_cap_frac=None``
exactly: every kept slot is attended (no weight-ranked B1 truncation — the
fused kernel has no second gather to shrink, so the cap is moot).

Layout contract (see ``src/repro/kernels/README.md``):

* grid = (B,) with B = batch * kv_heads; one launch decodes ``kw`` window
  positions per slot (kw = 1 is the classic single-token step).  Query
  rows are laid out position-major inside the kv-head block: row
  r = j * group + g is window position j, group member g.
* per grid step everything is m-resident, so VMEM holds the codes block,
  the f32 score/weight rows (kw·group × m), and two (blk, 1, d) block
  staging buffers per stream (K and V).  ``ops.fused_vmem_bytes`` sizes
  this; the pipeline falls back to the staged path when the estimate
  exceeds ``ops.FUSED_VMEM_BUDGET`` on a real TPU.
* ``rows`` are *final* cache coordinates: physical pool rows for a paged
  cache (translated through the page table before the call, exactly as the
  staged gathers do), plain cache positions otherwise.  Dead slots carry
  row 0 (the null page) and ``valid=False``.
* block runs have static length ``blk = coalesce_block(m, page_size)``
  (a divisor of both, so a coalesced copy can never cross a physical page
  boundary); coalescing is chosen per block when the kept count reaches
  ``coalesce_min_rows`` — below that, per-row copies of only the kept
  rows move fewer byte-equivalents.
* the double-buffer protocol: buffer slot = run index mod 2; the copy for
  run j+1 is started right after the wait for run j and before run j's
  flash update, so compute and DMA overlap.  Start and wait use the same
  predicate expressions (pure functions of the run index), so semaphore
  counts always match.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import NEG_INF, resolve_interpret

# Modeled fixed cost of one async copy, in byte-equivalents at HBM
# bandwidth (descriptor issue + DRAM row activation ≈ 2 KiB of streaming).
# Shared with ``analysis/costs.py`` so the kernel's coalescing decision and
# the roofline's DMA model agree by construction.
DMA_OVERHEAD_BYTES = 2048

# Widest block run the kernel will stage (rows); wider runs amortize the
# per-copy overhead no further but inflate the VMEM staging buffers.
MAX_BLOCK_ROWS = 64


def coalesce_block(m: int, page_size: int) -> int:
    """Static block-run length: a common divisor of ``m`` and ``page_size``.

    Dividing ``page_size`` guarantees an aligned block never straddles a
    physical page boundary in the pool; dividing ``m`` lets the kept
    bitmap be reshaped to (m // blk, blk) with no remainder.
    """
    blk = math.gcd(m, page_size)
    while blk > MAX_BLOCK_ROWS and blk % 2 == 0:
        blk //= 2
    return blk


def coalesce_min_rows(blk: int, d: int, kv_bytes: int = 2) -> int:
    """Kept-rows threshold above which ONE blk-row copy beats per-row DMA.

    Per-row cost for c kept rows is c·(OVH + d·kv_bytes) byte-equivalents
    per stream; the coalesced block costs OVH + blk·d·kv_bytes.  Solve for
    the break-even c (identical for K and V, so the factor two cancels).
    """
    row = d * kv_bytes
    return max(1, min(blk, -(-(DMA_OVERHEAD_BYTES + blk * row)
                             // (DMA_OVERHEAD_BYTES + row))))


def _fused_decode_kernel(
    qf_ref,  # (1, kw*group, d) — whole queries, final attention
    qe_ref,  # (1, kw*group, d2) — even channels (low nibbles)
    qo_ref,  # (1, kw*group, d2) — odd channels (high nibbles)
    packed_ref,  # (1, m, d2) uint8 — gathered candidate INT4 codes
    scale_ref,  # (1, m) f32
    zero_ref,  # (1, m) f32
    valid_ref,  # (1, kw, m) int8 — per-position live candidate slots
    rows_ref,  # (1, m) i32 — cache rows (physical for paged pools)
    p_ref,  # (1,) f32 — top-p threshold
    palive_ref,  # (1, nb) int8 — page-survivor mask at blk granularity
    k_hbm,  # ANY: (b, n, hkv, d) contiguous or (P, hkv, d) pooled
    v_hbm,  # ANY: same layout as k_hbm
    out_ref,  # (1, kw*group, d)
    kept_ref,  # (1, kw, m) int8 — per-position survivors (GQA group union)
    w_ref,  # (1, kw, m) f32 — group-max normalized weights (H2O mass key)
    thresh_ref,  # (1, kw*group) f32 — applied threshold per query row
    k_scr,  # VMEM (2, blk, 1, d) cache-dtype double-buffered block scratch
    v_scr,  # VMEM (2, blk, 1, d)
    sem_k,  # DMA semaphores, one per buffer slot
    sem_v,
    *,
    sm_scale: float,
    iters: int,
    hkv: int,
    pooled: bool,
    kw: int,
    blk: int,
    page_size: int,
    coal_min: int,
    hier: bool,
):
    i = pl.program_id(0)
    bi = i // hkv
    hi = i % hkv

    qe = qe_ref[0].astype(jnp.float32)  # (kg, d2)
    qo = qo_ref[0].astype(jnp.float32)
    codes = packed_ref[0]  # (m, d2) uint8
    scale = scale_ref[0].astype(jnp.float32)  # (m,)
    zero = zero_ref[0].astype(jnp.float32)
    valid_k = valid_ref[0] != 0  # (kw, m) — causal window mask pre-folded
    p = p_ref[0]
    palive = palive_ref[0] != 0  # (nb,) — blocks with >= 1 live slot
    kg, d = qf_ref.shape[1], qf_ref.shape[2]
    d2 = codes.shape[1]
    group = kg // kw
    m = codes.shape[0]

    # --- Stage 1: INT4 score estimate (spgemv math, dequant in epilogue) ---
    # One codes read serves all kw positions — the estimate is amortized
    # across the window (Tactic: survivor sets are temporally stable).
    qsum = jnp.sum(qe + qo, axis=-1, keepdims=True)  # (kg, 1)
    if not hier:
        # Flat pipeline: every candidate slot is live by construction, so
        # one (kg, d2) x (d2, m) matmul pair keeps the MXU fully fed.
        low = (codes & 0x0F).astype(jnp.float32)
        high = (codes >> 4).astype(jnp.float32)
        dot = jnp.dot(qe, low.T, preferred_element_type=jnp.float32)
        dot += jnp.dot(qo, high.T, preferred_element_type=jnp.float32)
        est = (dot * scale[None, :] + qsum * zero[None, :]) * sm_scale
    else:
        # Hierarchical page nucleus: the candidate staging loop walks the
        # same blk-aligned blocks stage 4 streams and **early-outs whole
        # dead pages** behind a cond — nucleus-pruned pages skip the nibble
        # unpack, both matmuls, and the epilogue, so estimate compute
        # scales with the *surviving* page count.  Dead blocks score 0;
        # their slots are invalid, so stage 2 masks them to -inf anyway.
        def est_block(j, acc):
            def live_blk(_):
                cb = jax.lax.dynamic_slice(codes, (j * blk, 0), (blk, d2))
                low_b = (cb & 0x0F).astype(jnp.float32)
                high_b = (cb >> 4).astype(jnp.float32)
                sc = jax.lax.dynamic_slice(scale, (j * blk,), (blk,))
                zr = jax.lax.dynamic_slice(zero, (j * blk,), (blk,))
                dotb = jnp.dot(qe, low_b.T,
                               preferred_element_type=jnp.float32)
                dotb += jnp.dot(qo, high_b.T,
                                preferred_element_type=jnp.float32)
                return (dotb * sc[None, :] + qsum * zr[None, :]) * sm_scale

            estb = jax.lax.cond(
                palive[j], live_blk,
                lambda _: jnp.zeros((kg, blk), jnp.float32), None)
            return jax.lax.dynamic_update_slice(acc, estb, (0, j * blk))

        est = jax.lax.fori_loop(0, m // blk, est_block,
                                jnp.zeros((kg, m), jnp.float32))

    # Query row r = j * group + g sees position j's candidate validity.
    valid_q = jnp.broadcast_to(
        valid_k[:, None, :], (kw, group, m)).reshape(kg, m)

    # --- Stage 2: masked softmax — the weight rows stay in VMEM ----------
    neg = jnp.finfo(jnp.float32).min
    est = jnp.where(valid_q, est, neg)
    mx = jnp.max(est, axis=-1, keepdims=True)
    unnorm = jnp.where(valid_q, jnp.exp(est - mx), 0.0)
    denom = jnp.sum(unnorm, axis=-1, keepdims=True)
    w = unnorm / jnp.maximum(denom, jnp.finfo(jnp.float32).tiny)  # (kg, m)

    # --- Stage 3: fixed-trip top-p binary search (Algorithm 1) -----------
    lo = jnp.zeros((kg,), jnp.float32)
    hi_w = jnp.max(w, axis=-1)

    def search(_, carry):
        lo, hi_w = carry
        mid = 0.5 * (lo + hi_w)
        mass = jnp.sum(jnp.where(w >= mid[:, None], w, 0.0), axis=-1)
        ok = mass >= p
        return jnp.where(ok, mid, lo), jnp.where(ok, hi_w, mid)

    lo, hi_w = jax.lax.fori_loop(0, iters, search, (lo, hi_w))
    kept_rows = (w >= lo[:, None]) & valid_q  # (kg, m) per query row
    # GQA group union per window position, then window union = the DMA set.
    kept_pos = kept_rows.reshape(kw, group, m).any(axis=1)  # (kw, m)
    kept = kept_pos.any(axis=0)  # (m,) — rows streamed from HBM
    # Each query row attends its own position's group-union kept set.
    amask = jnp.broadcast_to(
        kept_pos[:, None, :], (kw, group, m)).reshape(kg, m)

    # --- Stage 4: block-run coalesced, double-buffered streaming attend ---
    # The union kept bitmap is viewed as nb = m / blk aligned block runs.
    # Dead blocks (no survivor) cost nothing; surviving blocks are staged
    # through two ping-ponged VMEM buffers, coalesced into one blk-row
    # copy when dense enough (>= coal_min kept rows, page-run contiguous),
    # per-row otherwise.  DMA of run j+1 overlaps run j's flash update.
    qf = qf_ref[0].astype(jnp.float32)  # (kg, d)
    rows = rows_ref[0]  # (m,) i32
    nb = m // blk
    rows2 = rows.reshape(nb, blk)
    kept2 = kept.reshape(nb, blk)
    # Page-survivor AND: a nucleus-dead page has no valid slot, so kept2 is
    # already all-False there — the AND is semantically a no-op but makes
    # the structural contract explicit: dead pages never issue DMA.
    blk_any = kept2.any(axis=1) & palive  # (nb,)
    blk_cnt = kept2.sum(axis=1)  # (nb,)
    base = rows2[:, 0]
    span = jax.lax.broadcasted_iota(jnp.int32, (nb, blk), 1)
    contig = jnp.all(rows2 == base[:, None] + span, axis=1)
    same_page = (base // page_size) == ((base + blk - 1) // page_size)
    blk_coal = contig & same_page & (blk_cnt >= coal_min)

    def src_rows(start, length):
        if pooled:
            return (k_hbm.at[pl.ds(start, length), pl.ds(hi, 1)],
                    v_hbm.at[pl.ds(start, length), pl.ds(hi, 1)])
        return (k_hbm.at[bi, pl.ds(start, length), pl.ds(hi, 1)],
                v_hbm.at[bi, pl.ds(start, length), pl.ds(hi, 1)])

    def dma_block(j, ok, start):
        # Start and wait share these predicate expressions (pure functions
        # of j), so every started copy is waited exactly once.
        slot = j % 2
        pred_c = ok & blk_any[j] & blk_coal[j]
        pred_r = ok & blk_any[j] & jnp.logical_not(blk_coal[j])

        @pl.when(pred_c)
        def _():
            # One coalesced blk-row copy per stream; never crosses a page
            # boundary (blk divides page_size and the run is aligned).
            ks, vs = src_rows(rows2[j, 0], blk)
            ck = pltpu.make_async_copy(ks, k_scr.at[slot], sem_k.at[slot])
            cv = pltpu.make_async_copy(vs, v_scr.at[slot], sem_v.at[slot])
            if start:
                ck.start()
                cv.start()
            else:
                ck.wait()
                cv.wait()

        for t in range(blk):
            @pl.when(pred_r & kept2[j, t])
            def _(t=t):
                # Sparse block: fetch only the kept rows (traffic-exact).
                ks, vs = src_rows(rows2[j, t], 1)
                ck = pltpu.make_async_copy(
                    ks, k_scr.at[slot, pl.ds(t, 1)], sem_k.at[slot])
                cv = pltpu.make_async_copy(
                    vs, v_scr.at[slot, pl.ds(t, 1)], sem_v.at[slot])
                if start:
                    ck.start()
                    cv.start()
                else:
                    ck.wait()
                    cv.wait()

    def attend_block(j, carry):
        slot = j % 2
        dma_block(j, True, start=False)  # block j landed in buffer slot
        # Prefetch block j+1 into the other buffer before touching j's
        # data — the copy runs during this block's flash update.
        dma_block(jnp.minimum(j + 1, nb - 1), j + 1 < nb, start=True)

        kb = k_scr[slot, :, 0].astype(jnp.float32)  # (blk, d)
        vb = v_scr[slot, :, 0].astype(jnp.float32)
        # Rows never copied this block (pruned, or a dead block skipped
        # entirely) hold stale buffer data — zero them so garbage can
        # never reach the accumulator through a 0·NaN product.
        keep_col = jax.lax.dynamic_slice(kept, (j * blk,), (blk,))
        kb = jnp.where(keep_col[:, None], kb, 0.0)
        vb = jnp.where(keep_col[:, None], vb, 0.0)

        s = jnp.dot(qf, kb.T, preferred_element_type=jnp.float32) * sm_scale
        am = jax.lax.dynamic_slice(amask, (0, j * blk), (kg, blk))
        s = jnp.where(am, s, NEG_INF)  # finite mask — no inf-inf NaNs

        m_run, l_run, acc = carry
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_run - m_new)
        p_t = jnp.where(am, jnp.exp(s - m_new), 0.0)
        l_new = l_run * alpha + jnp.sum(p_t, axis=-1, keepdims=True)
        acc_new = acc * alpha + jnp.dot(p_t, vb,
                                        preferred_element_type=jnp.float32)
        new = (m_new, l_new, acc_new)
        # Dead blocks are a no-op (alpha = 1, p_t = 0) but skip the select
        # anyway so a fully-masked block can never perturb the carry.
        return jax.tree_util.tree_map(
            lambda n, c: jnp.where(blk_any[j], n, c), new, carry)

    init = (jnp.full((kg, 1), NEG_INF, jnp.float32),
            jnp.zeros((kg, 1), jnp.float32),
            jnp.zeros((kg, d), jnp.float32))
    dma_block(0, True, start=True)  # warm the first buffer
    _, l_run, acc = jax.lax.fori_loop(0, nb, attend_block, init)
    out = acc / jnp.maximum(l_run, 1e-30)
    out = jnp.where(l_run > 0.0, out, 0.0)  # fully-pruned rows emit zeros

    out_ref[0] = out.astype(out_ref.dtype)
    kept_ref[0] = kept_pos.astype(jnp.int8)
    w_ref[0] = w.reshape(kw, group, m).max(axis=1)  # group-max (H2O key)
    thresh_ref[0] = lo


@functools.partial(
    jax.jit,
    static_argnames=("sm_scale", "iters", "hkv", "pooled", "page_size",
                     "hierarchical", "interpret"),
)
def fused_decode_rows(
    qf: jax.Array,  # (B, kw*group, d) — B = batch * kv_heads
    q_even: jax.Array,  # (B, kw*group, d//2)
    q_odd: jax.Array,  # (B, kw*group, d//2)
    packed: jax.Array,  # (B, m, d//2) uint8 — gathered candidate codes
    scale: jax.Array,  # (B, m) f32
    zero: jax.Array,  # (B, m) f32
    valid: jax.Array,  # (B, kw, m) bool/int8 — per-position validity
    rows: jax.Array,  # (B, m) i32 cache rows
    p: jax.Array,  # scalar f32
    keys: jax.Array,  # (b, n, hkv, d) or (P, hkv, d) — stays in HBM
    values: jax.Array,  # same layout as keys
    *,
    sm_scale: float,
    iters: int = 24,
    hkv: int,
    pooled: bool,
    page_size: int = 64,
    hierarchical: bool = False,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One launch per call: (out (B, kw*group, d), kept (B, kw, m) int8,
    slot_weights (B, kw, m) f32, threshold (B, kw*group) f32).

    ``hierarchical`` switches stage 1 to the blocked page-survivor walk:
    the (B, nb) page-alive mask is derived from ``valid`` (window union at
    blk granularity) and whole dead pages skip estimate compute and DMA.
    """
    interpret = resolve_interpret(interpret)
    B, kg, d = qf.shape
    kw = valid.shape[1]
    m = packed.shape[1]
    d2 = packed.shape[2]
    blk = coalesce_block(m, page_size)
    nb = m // blk
    coal_min = coalesce_min_rows(blk, d, keys.dtype.itemsize)
    valid = valid.astype(jnp.int8)
    # Window union at block granularity — equals the selector's page
    # survivor set (nucleus-dead pages carry valid=False in every slot).
    palive = ((valid != 0).any(axis=1)
              .reshape(B, nb, blk).any(axis=-1).astype(jnp.int8))
    p_arr = jnp.broadcast_to(jnp.asarray(p, jnp.float32), (1,))
    return pl.pallas_call(
        functools.partial(_fused_decode_kernel, sm_scale=sm_scale,
                          iters=iters, hkv=hkv, pooled=pooled, kw=kw,
                          blk=blk, page_size=page_size, coal_min=coal_min,
                          hier=hierarchical),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, kg, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, kg, d2), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, kg, d2), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m, d2), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1, kw, m), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1, nb), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),  # K cache/pool, HBM
            pl.BlockSpec(memory_space=pltpu.ANY),  # V cache/pool, HBM
        ],
        out_specs=[
            pl.BlockSpec((1, kg, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, kw, m), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, kw, m), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, kg), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, kg, d), qf.dtype),
            jax.ShapeDtypeStruct((B, kw, m), jnp.int8),
            jax.ShapeDtypeStruct((B, kw, m), jnp.float32),
            jax.ShapeDtypeStruct((B, kg), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, blk, 1, d), keys.dtype),
            pltpu.VMEM((2, blk, 1, d), values.dtype),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(qf, q_even, q_odd, packed, scale, zero, valid, rows, p_arr, palive,
      keys, values)
