"""Hierarchical top-p sparse prefill: kernel, wrapper, model, engine.

Levels, mirroring how the feature is layered:

* kernel — ``sparse_prefill_rows`` (interpret mode) vs the dense masked
  oracle ``sparse_prefill_ref`` on adversarial survivor patterns
  (all-live / all-dead / single-page / random), contiguous and pooled
  (shuffled physical pages must be bit-identical to contiguous);
* wrapper — ``top_p=1.0`` is bit-exact vs the dense ``mha_attention``
  oracle in both layouts at ragged lengths; the page-survivor set is
  monotone in p with the causal frontier always forced; the kernel and
  the jnp bias fallback agree; ``sparse_prefill_fits`` falls back
  automatically when the tile would overflow VMEM;
* model — chunked prefill across a partial page boundary leaves the
  pool's Quest min/max metadata bit-equal to ground truth recomputed
  from the pool rows (the freshly-full-page merge skip is invisible);
* engine — ``prefill_top_p=1.0`` is token-exact vs the dense engine for
  every paged selector under prefix sharing + COW at ragged lengths
  (prefix-cache insertion unchanged), and ``prefill_top_p=0.9`` serves
  the same workload end to end with live-page telemetry flowing through
  ``session_run_stats``.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.attention import mha_attention
from repro.core.selectors import gather_logical_rows
from repro.kernels.fused_decode.kernel import coalesce_block
from repro.kernels.sparse_prefill.ops import (
    SPARSE_PREFILL_VMEM_BUDGET,
    prefill_page_survivors,
    sparse_prefill_attend,
    sparse_prefill_fits,
    sparse_prefill_vmem_bytes,
)
from repro.kernels.sparse_prefill.kernel import sparse_prefill_rows
from repro.kernels.sparse_prefill.ref import sparse_prefill_ref
from repro.serving import DecodeEngine
from repro.serving.paged_cache import PageAllocator
from tests.test_prefix_cache import PAGED_SELECTORS, _shared_requests


def _page_meta(k, kv_len, page_size):
    """Quest min/max per page, rows >= kv_len excluded (model convention)."""
    b, n, hkv, d = k.shape
    neg = jnp.finfo(jnp.float32).min
    live = (jnp.arange(n)[None, :] < kv_len[:, None])[..., None, None]
    k32 = k.astype(jnp.float32)
    grid = (b, n // page_size, page_size, hkv, d)
    kmax = jnp.where(live, k32, neg).reshape(grid).max(axis=2)
    kmin = jnp.where(live, k32, -neg).reshape(grid).min(axis=2)
    return kmax, kmin


# ---------------------------------------------------------------------------
# Kernel vs dense masked oracle
# ---------------------------------------------------------------------------

def _kernel_setup(rng, *, b=2, hkv=2, group=2, nqb=2, q_block=32, n=128,
                  ps=16, d=32):
    B = b * hkv
    qr = q_block * group
    q = jnp.asarray(rng.normal(size=(B, nqb, qr, d)), jnp.float32)
    keys = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    values = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    blk = coalesce_block(ps, ps)
    nb = n // blk
    # Ragged: slot 0 full, slot 1 mid-page; queries end at the prefix end.
    lens = np.array([n, n - 21], np.int32)[:b]
    kv_b = np.repeat(lens, hkv).astype(np.int32)  # (B,) slot-major
    off_b = kv_b - nqb * q_block
    rows = np.broadcast_to(np.arange(nb, dtype=np.int32) * blk, (B, nb))
    return (q, keys, values, jnp.asarray(rows), jnp.asarray(kv_b),
            jnp.asarray(off_b), blk, nb)


def _gather_heads(x, hkv):
    """(b, n, hkv, d) -> kernel-slot-major (b*hkv, n, d)."""
    b, n, _, d = x.shape
    return jnp.moveaxis(x, 2, 1).reshape(b * hkv, n, d)


@pytest.mark.parametrize("pattern",
                         ["all_live", "all_dead", "single_page", "random"])
def test_kernel_vs_ref_survivor_patterns(rng, pattern):
    (q, keys, values, rows, kv_b, off_b, blk, nb) = _kernel_setup(rng)
    B, nqb, qr, d = q.shape
    if pattern == "all_live":
        surv = np.ones((B, nqb, nb), np.int8)
    elif pattern == "all_dead":
        surv = np.zeros((B, nqb, nb), np.int8)
    elif pattern == "single_page":
        surv = np.zeros((B, nqb, nb), np.int8)
        surv[:, :, 3] = 1
    else:
        surv = (rng.random((B, nqb, nb)) < 0.5).astype(np.int8)
    surv = jnp.asarray(surv)
    out = sparse_prefill_rows(
        q, surv, rows, kv_b[:, None], off_b[:, None], keys, values,
        sm_scale=d ** -0.5, hkv=2, group=2, q_block=32,
        pooled=False, page_size=16, interpret=True)
    ref = sparse_prefill_ref(
        q, _gather_heads(keys, 2), _gather_heads(values, 2), surv,
        kv_len=kv_b, q_offset=off_b, group=2, q_block=32, sm_scale=d ** -0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    if pattern == "all_dead":
        assert not np.any(np.asarray(out)), "fully-masked rows emit zeros"


def test_kernel_pooled_bitexact_vs_contiguous(rng):
    """Shuffled physical pages addressed through `rows` reproduce the
    contiguous kernel bit for bit — the DMA source moves, nothing else."""
    (q, keys, values, rows, kv_b, off_b, blk, nb) = _kernel_setup(rng, b=1)
    B, nqb, qr, d = q.shape
    surv = jnp.asarray((rng.random((B, nqb, nb)) < 0.6).astype(np.int8))
    out_c = sparse_prefill_rows(
        q, surv, rows, kv_b[:, None], off_b[:, None], keys, values,
        sm_scale=d ** -0.5, hkv=2, group=2, q_block=32,
        pooled=False, page_size=16, interpret=True)

    # Scatter the logical pages into a shuffled pool (pool row layout:
    # (P, hkv, d), page p_phys holds rows p_phys*ps..).
    ps, n = 16, keys.shape[1]
    n_pages = n // ps
    perm = rng.permutation(n_pages + 2)[:n_pages]  # spare physical pages
    pool_k = np.zeros(((n_pages + 2) * ps, 2, d), np.float32)
    pool_v = np.zeros_like(pool_k)
    for lp, pp in enumerate(perm):
        pool_k[pp * ps:(pp + 1) * ps] = np.asarray(keys[0, lp * ps:(lp + 1) * ps])
        pool_v[pp * ps:(pp + 1) * ps] = np.asarray(values[0, lp * ps:(lp + 1) * ps])
    prow = (perm.astype(np.int32) * ps)[:, None] + np.arange(0, ps, blk,
                                                             dtype=np.int32)
    prow = np.broadcast_to(prow.reshape(-1), (B, nb))
    out_p = sparse_prefill_rows(
        q, surv, jnp.asarray(prow), kv_b[:, None], off_b[:, None],
        jnp.asarray(pool_k), jnp.asarray(pool_v),
        sm_scale=d ** -0.5, hkv=2, group=2, q_block=32,
        pooled=True, page_size=16, interpret=True)
    np.testing.assert_array_equal(np.asarray(out_c), np.asarray(out_p))


# ---------------------------------------------------------------------------
# Wrapper: p=1.0 oracle, monotonicity, kernel-vs-fallback, VMEM gate
# ---------------------------------------------------------------------------

def test_p1_bitexact_contiguous_ragged(rng):
    b, n, ps, hq, hkv, d = 2, 96, 16, 4, 2, 32
    s = 83  # ragged: not a page multiple; keys padded to one
    q = jnp.asarray(rng.normal(size=(b, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    kmax, kmin = _page_meta(k, jnp.full((b,), s, jnp.int32), ps)
    out = sparse_prefill_attend(q, k, v, kmax, kmin, top_p=1.0,
                                page_size=ps, kv_len=s)
    oracle = mha_attention(q, k, v, causal=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle))


@pytest.mark.parametrize("selector", PAGED_SELECTORS)
def test_p1_bitexact_all_selectors(rng, selector):
    """The oracle bypass is selector-independent — pin it anyway, since
    the acceptance bar names every selector at ragged lengths."""
    b, n, ps, hq, hkv, d = 1, 80, 16, 4, 2, 32
    s = 71
    q = jnp.asarray(rng.normal(size=(b, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    kmax, kmin = _page_meta(k, jnp.full((b,), s, jnp.int32), ps)
    out = sparse_prefill_attend(q, k, v, kmax, kmin, top_p=1.0,
                                page_size=ps, kv_len=s)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(mha_attention(q, k, v, causal=True)))


def test_p1_bitexact_pooled(rng):
    ps, hq, hkv, d, max_pages = 16, 4, 2, 32, 6
    s, off = 23, 41
    kv_len = off + s
    pool_pages = 12
    q = jnp.asarray(rng.normal(size=(1, s, hq, d)), jnp.float32)
    pool_k = jnp.asarray(rng.normal(size=(pool_pages * ps, hkv, d)),
                         jnp.float32)
    pool_v = jnp.asarray(rng.normal(size=(pool_pages * ps, hkv, d)),
                         jnp.float32)
    pt = jnp.asarray(rng.permutation(pool_pages)[:max_pages].astype(np.int32)
                     )[None]
    meta_k = pool_k.reshape(pool_pages, ps, hkv, d)
    out = sparse_prefill_attend(
        q, pool_k, pool_v, meta_k.max(axis=1), meta_k.min(axis=1),
        top_p=1.0, page_size=ps, kv_len=kv_len, q_offset=off, page_table=pt)
    k_log = gather_logical_rows(pool_k, pt, ps)
    v_log = gather_logical_rows(pool_v, pt, ps)
    oracle = mha_attention(q, k_log, v_log, causal=True, q_offset=off)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle))


def test_survivors_monotone_in_p_and_frontier_forced(rng):
    b, s, hq, hkv, d, ps = 1, 256, 4, 2, 32, 16
    q_block = 64
    n = 256
    q = jnp.asarray(rng.normal(size=(b, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, n, hkv, d)) * 2.0, jnp.float32)
    kv_len = jnp.full((b,), n, jnp.int32)
    kmax, kmin = _page_meta(k, kv_len, ps)
    off = jnp.zeros((b,), jnp.int32)
    prev = None
    for p in (0.2, 0.5, 0.8, 0.95):
        surv, part = prefill_page_survivors(
            q, kmax, kmin, top_p=p, page_size=ps, kv_len=kv_len,
            q_offset=off, q_block=q_block)
        surv = np.asarray(surv)
        assert not np.any(surv & ~np.asarray(part))
        if prev is not None:
            assert np.all(~prev | surv), f"survivors not monotone at p={p}"
        prev = surv
        # Every query block keeps the page holding its own queries.
        nqb = s // q_block
        for qb in range(nqb):
            own = (qb * q_block) // ps
            assert surv[:, qb, :, own:own + q_block // ps].all(), \
                f"frontier page pruned at p={p}, block {qb}"


def test_attend_kernel_matches_bias_fallback(rng):
    b, n, ps, hq, hkv, d = 2, 128, 16, 4, 2, 32
    s = 97
    q = jnp.asarray(rng.normal(size=(b, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    kv_len = jnp.asarray([s, s - 30], jnp.int32)
    kmax, kmin = _page_meta(k, kv_len, ps)
    kw = dict(top_p=0.8, page_size=ps, kv_len=kv_len, q_block=32,
              return_aux=True)
    out_k, aux_k = sparse_prefill_attend(q, k, v, kmax, kmin,
                                         use_kernel=True, interpret=True,
                                         **kw)
    out_j, aux_j = sparse_prefill_attend(q, k, v, kmax, kmin,
                                         use_kernel=False, **kw)
    np.testing.assert_array_equal(np.asarray(aux_k["survivors"]),
                                  np.asarray(aux_j["survivors"]))
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_j),
                               rtol=2e-5, atol=2e-5)
    surv = np.asarray(aux_k["survivors"])
    part = np.asarray(aux_k["participate"])
    assert surv.sum() < part.sum(), "p=0.8 must actually prune pages"


def test_vmem_gate_and_automatic_fallback(rng):
    # Arithmetic pins: the budget is dominated by per-tile terms, so a
    # serving-shaped tile fits at any context …
    assert sparse_prefill_fits(65536, 64, 4, 2, interpret=False)
    assert (sparse_prefill_vmem_bytes(8192, 64, 4, 2)
            <= sparse_prefill_vmem_bytes(65536, 64, 4, 2))
    # … while an oversized (q_block × group × d) tile does not.
    big = dict(q_block=1024)
    assert not sparse_prefill_fits(65536, 256, 8, 2, interpret=False, **big)
    assert (sparse_prefill_vmem_bytes(65536, 256, 8, 2, **big)
            > SPARSE_PREFILL_VMEM_BUDGET)

    # Automatic fallback: use_kernel=True + interpret=False + a tile that
    # fails the gate must take the jnp bias path (a real pallas_call
    # would abort on CPU), and match the explicit fallback exactly.
    b, s, n, ps, hq, hkv, d = 1, 1024, 2048, 64, 8, 1, 256
    q = jnp.asarray(rng.normal(size=(b, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    kmax, kmin = _page_meta(k, jnp.full((b,), s, jnp.int32), ps)
    kw = dict(top_p=0.9, page_size=ps, kv_len=s, q_block=1024)
    out = sparse_prefill_attend(q, k, v, kmax, kmin, use_kernel=True,
                                interpret=False, **kw)
    ref = sparse_prefill_attend(q, k, v, kmax, kmin, use_kernel=False, **kw)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# Model: chunked-prefill Quest metadata stays ground-truth exact
# ---------------------------------------------------------------------------

def test_chunk_metadata_bitexact_across_partial_boundary(rng):
    """Two chunks meeting mid-page: page 1 is written by both (the j==0
    merge path), pages 0 and 2 are single-writer (page 2 freshly full in
    chunk 2, page 0 skipped entirely by the fresh-page merge skip).  The
    pool metadata must equal min/max recomputed from the pool rows."""
    from repro.models import init_paged_decode_state, init_params, prefill_chunk
    cfg = get_smoke_config("qwen2-1.5b")
    ps = cfg.twilight.page_size
    params = init_params(cfg, jax.random.PRNGKey(0))
    alloc = PageAllocator(9)
    state = init_paged_decode_state(cfg, 1, alloc.num_pages)
    pages = alloc.alloc(3)
    pt = np.zeros((4,), np.int32)
    pt[:3] = pages
    total = 2 * ps + ps // 2  # 2.5 pages
    prompt = rng.integers(8, cfg.vocab_size, total).astype(np.int32)
    c1 = ps + ps // 2  # chunk 1 ends mid-page-1
    buf1 = np.zeros((2 * ps,), np.int32)
    buf1[:c1] = prompt[:c1]
    _, state, _ = prefill_chunk(params, cfg, state, jnp.asarray(buf1),
                                jnp.asarray(pt), jnp.int32(0), jnp.int32(0),
                                jnp.int32(c1), False)
    buf2 = np.zeros((2 * ps,), np.int32)
    buf2[:total - c1] = prompt[c1:]
    _, state, _ = prefill_chunk(params, cfg, state, jnp.asarray(buf2),
                                jnp.asarray(pt), jnp.int32(0), jnp.int32(c1),
                                jnp.int32(total - c1), True)

    resident = [ps, ps, ps // 2]  # live rows per logical page
    for li, blk in enumerate(state["blocks"]):
        if "pmax" not in blk:
            continue
        k = np.asarray(blk["k"], np.float32)
        for lp, phys in enumerate(pages):
            rows = k[:, phys * ps:phys * ps + resident[lp]]
            np.testing.assert_array_equal(
                np.asarray(blk["pmax"][:, phys]), rows.max(axis=1),
                err_msg=f"layer {li} page {lp}: pmax drifted")
            np.testing.assert_array_equal(
                np.asarray(blk["pmin"][:, phys]), rows.min(axis=1),
                err_msg=f"layer {li} page {lp}: pmin drifted")


def test_contiguous_prefill_sparse_branch_close_to_dense(rng):
    """Small prompt: the causal frontier + recent window force every page,
    so the sparse contiguous prefill reproduces the dense logits."""
    from repro.models import init_params, prefill
    cfg = get_smoke_config("qwen2-1.5b")
    sp = cfg.replace(twilight=dataclasses.replace(cfg.twilight,
                                                  prefill_top_p=0.5))
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(
        rng.integers(8, cfg.vocab_size, (2, 19)).astype(np.int32))}
    lg_dense, _ = prefill(params, cfg, batch, n_max=32)
    lg_sparse, _ = prefill(params, sp, batch, n_max=32)
    np.testing.assert_allclose(np.asarray(lg_sparse), np.asarray(lg_dense),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Engine: oracle token-exactness + approximate serving with telemetry
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("selector", PAGED_SELECTORS)
def test_engine_prefill_oracle_token_exact(rng, selector):
    """prefill_top_p=1.0 routes chunked prefill through the sparse
    wrapper's dense bypass — token-exact vs the dense engine under prefix
    sharing + COW at ragged lengths, so prefix-cache insertion (and the
    decode that follows) is provably unchanged."""
    cfg = get_smoke_config("qwen2-1.5b")
    cfg = cfg.replace(twilight=dataclasses.replace(
        cfg.twilight, selector=selector))
    sp_cfg = cfg.replace(twilight=dataclasses.replace(
        cfg.twilight, prefill_top_p=1.0))
    reqs = _shared_requests(rng, cfg)
    base = DecodeEngine(cfg, batch_size=2, cache_capacity=64, seed=7,
                        paged=True, prefix_share=True)
    sp = DecodeEngine(sp_cfg, params=base.params, batch_size=2,
                      cache_capacity=64, seed=7, paged=True,
                      prefix_share=True)
    want = {r.uid: r.tokens for r in base.generate(reqs)}
    got = {r.uid: r.tokens for r in sp.generate(reqs)}
    assert got == want
    assert sp.last_prefix_hits >= 2
    assert sp.last_cow_copies >= 1


def test_engine_sparse_prefill_serves_with_telemetry(rng):
    """prefill_top_p=0.9 end to end: the shared-prefix + COW workload
    serves, and the live-page counters flow into session_run_stats."""
    cfg = get_smoke_config("qwen2-1.5b")
    cfg = cfg.replace(twilight=dataclasses.replace(
        cfg.twilight, prefill_top_p=0.9, collect_run_stats=True))
    reqs = _shared_requests(rng, cfg)
    engine = DecodeEngine(cfg, batch_size=2, cache_capacity=64, seed=7,
                          paged=True, prefix_share=True)
    results = {r.uid: r for r in engine.generate(reqs)}
    assert set(results) == {r.uid for r in reqs}
    for r in reqs:
        got = results[r.uid]
        assert len(got.tokens) == r.max_new_tokens
        assert all(0 <= t < cfg.vocab_size for t in got.tokens)
    assert engine.last_prefix_hits >= 2
    stats = engine.session_run_stats()
    assert stats is not None
    assert stats["prefill_qblocks"] > 0
    assert stats["prefill_pages_cand"] > 0
    assert 0 < stats["prefill_pages_live"] <= stats["prefill_pages_cand"]
    assert 0.0 < stats["prefill_live_frac"] <= 1.0
