"""Pallas kernel: the whole Twilight prune-and-attend, fused into ONE launch.

The staged compact decode path runs three Pallas launches per attention
layer per decode step — spgemv INT4 estimate, top-p threshold search,
gathered sparse attention — and round-trips the B0-length score rows,
weight rows, kept masks, and the optional B1 re-compaction index buffer
through HBM between every stage.  This kernel is the paper's central
systems contribution (§4.2: run the hierarchical prune *inside* the
attention kernel): per (slot, kv-head) grid step it

1. stages the candidate rows' packed INT4 codes into VMEM and computes the
   estimated scores with the dequantization folded into the matmul
   epilogue (exactly the spgemv kernel's math — two nibble matmuls on the
   MXU plus a rank-1 VPU epilogue),
2. normalizes them with a masked softmax — the weight row never leaves
   VMEM,
3. runs the fixed-trip top-p binary search (Algorithm 1) on the resident
   row, per query head, and unions the kept sets over the GQA group,
4. immediately performs the pruned sparse attention: surviving candidate
   rows are DMA'd from the fp16 K/V cache (contiguous or shared page pool)
   one at a time behind a ``lax.cond`` on the kept bit — **pruned rows are
   never read from HBM** — and folded into an online-softmax accumulator.

No scores, thresholds, or B1 index buffers are ever materialized in HBM;
the only O(m) outputs are the kept bitmap and the group-max slot weights,
which the serving engine is required to see (H2O page-mass maintenance).

Attention semantics match the staged pipeline with ``pruned_cap_frac=None``
exactly: every kept slot is attended (no weight-ranked B1 truncation — the
fused kernel has no second gather to shrink, so the cap is moot).

Layout contract (see ``src/repro/kernels/README.md``):

* grid = (B,) with B = batch * kv_heads; per grid step everything is
  m-resident, so VMEM holds the codes block (m × (d/2 + 8 + 1) bytes), the
  f32 score/weight rows (group × m × 4 bytes ×~3 live values), and two
  (1, 1, d) row-DMA scratch buffers.  ``ops.fused_vmem_bytes`` sizes this;
  the pipeline falls back to the staged path when the estimate exceeds
  ``ops.FUSED_VMEM_BUDGET`` on a real TPU.
* ``rows`` are *final* cache coordinates: physical pool rows for a paged
  cache (translated through the page table before the call, exactly as the
  staged gathers do), plain cache positions otherwise.  Dead slots carry
  row 0 (the null page) and ``valid=False``.
* queries arrive both whole (final attention) and nibble-de-interleaved
  (estimate), matching the spgemv packing — no in-kernel lane shuffles.
* the per-row survivor DMA is the traffic-exact formulation (reads exactly
  the B1 surviving rows); production blocking would batch page-aligned
  survivor runs behind double buffering — a pure perf refinement that
  cannot change results.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import NEG_INF, resolve_interpret


def _fused_decode_kernel(
    qf_ref,  # (1, group, d) — whole queries, final attention
    qe_ref,  # (1, group, d2) — even channels (low nibbles)
    qo_ref,  # (1, group, d2) — odd channels (high nibbles)
    packed_ref,  # (1, m, d2) uint8 — gathered candidate INT4 codes
    scale_ref,  # (1, m) f32
    zero_ref,  # (1, m) f32
    valid_ref,  # (1, m) int8 — live candidate slots
    rows_ref,  # (1, m) i32 — cache rows (physical for paged pools)
    p_ref,  # (1,) f32 — top-p threshold
    k_hbm,  # ANY: (b, n, hkv, d) contiguous or (P, hkv, d) pooled
    v_hbm,  # ANY: same layout as k_hbm
    out_ref,  # (1, group, d)
    kept_ref,  # (1, m) int8 — post-top-p survivors (GQA group union)
    w_ref,  # (1, m) f32 — group-max normalized weights (H2O mass key)
    thresh_ref,  # (1, group) f32 — applied threshold per query head
    k_scr,  # VMEM (1, 1, d) cache-dtype row scratch
    v_scr,  # VMEM (1, 1, d)
    sem_k,  # DMA semaphores
    sem_v,
    *,
    sm_scale: float,
    iters: int,
    hkv: int,
    pooled: bool,
):
    i = pl.program_id(0)
    bi = i // hkv
    hi = i % hkv

    qe = qe_ref[0].astype(jnp.float32)  # (group, d2)
    qo = qo_ref[0].astype(jnp.float32)
    codes = packed_ref[0]  # (m, d2) uint8
    low = (codes & 0x0F).astype(jnp.float32)
    high = (codes >> 4).astype(jnp.float32)
    scale = scale_ref[0].astype(jnp.float32)  # (m,)
    zero = zero_ref[0].astype(jnp.float32)
    valid = valid_ref[0] != 0  # (m,)
    p = p_ref[0]
    group, d = qf_ref.shape[1], qf_ref.shape[2]
    m = codes.shape[0]

    # --- Stage 1: INT4 score estimate (spgemv math, dequant in epilogue) ---
    dot = jnp.dot(qe, low.T, preferred_element_type=jnp.float32)
    dot += jnp.dot(qo, high.T, preferred_element_type=jnp.float32)
    qsum = jnp.sum(qe + qo, axis=-1, keepdims=True)  # (group, 1)
    est = (dot * scale[None, :] + qsum * zero[None, :]) * sm_scale

    # --- Stage 2: masked softmax — the weight row stays in VMEM ----------
    neg = jnp.finfo(jnp.float32).min
    est = jnp.where(valid[None, :], est, neg)
    mx = jnp.max(est, axis=-1, keepdims=True)
    unnorm = jnp.where(valid[None, :], jnp.exp(est - mx), 0.0)
    denom = jnp.sum(unnorm, axis=-1, keepdims=True)
    w = unnorm / jnp.maximum(denom, jnp.finfo(jnp.float32).tiny)  # (group, m)

    # --- Stage 3: fixed-trip top-p binary search (Algorithm 1) -----------
    lo = jnp.zeros((group,), jnp.float32)
    hi_w = jnp.max(w, axis=-1)

    def search(_, carry):
        lo, hi_w = carry
        mid = 0.5 * (lo + hi_w)
        mass = jnp.sum(jnp.where(w >= mid[:, None], w, 0.0), axis=-1)
        ok = mass >= p
        return jnp.where(ok, mid, lo), jnp.where(ok, hi_w, mid)

    lo, hi_w = jax.lax.fori_loop(0, iters, search, (lo, hi_w))
    kept_q = (w >= lo[:, None]) & valid[None, :]  # (group, m) per query head
    kept = kept_q.any(axis=0)  # (m,) GQA group union — the loaded set

    # --- Stage 4: pruned sparse attention over the survivors -------------
    # Surviving rows are DMA'd from the fp cache one at a time behind the
    # kept bit: pruned rows cost no HBM traffic at all (the B1-scaled read
    # the staged path needs a weight-ranked re-compaction to approximate).
    qf = qf_ref[0].astype(jnp.float32)  # (group, d)
    rows = rows_ref[0]  # (m,) i32

    def attend(t, carry):
        def load_and_update(carry):
            m_run, l_run, acc = carry
            row = rows[t]
            if pooled:
                src_k = k_hbm.at[pl.ds(row, 1), pl.ds(hi, 1)]
                src_v = v_hbm.at[pl.ds(row, 1), pl.ds(hi, 1)]
            else:
                src_k = k_hbm.at[bi, pl.ds(row, 1), pl.ds(hi, 1)]
                src_v = v_hbm.at[bi, pl.ds(row, 1), pl.ds(hi, 1)]
            ck = pltpu.make_async_copy(src_k, k_scr, sem_k)
            cv = pltpu.make_async_copy(src_v, v_scr, sem_v)
            ck.start()
            cv.start()
            ck.wait()
            cv.wait()
            k_row = k_scr[0, 0].astype(jnp.float32)  # (d,)
            v_row = v_scr[0, 0].astype(jnp.float32)
            s = jnp.sum(qf * k_row[None, :], axis=-1,
                        keepdims=True) * sm_scale  # (group, 1)
            m_new = jnp.maximum(m_run, s)
            alpha = jnp.exp(m_run - m_new)
            p_t = jnp.exp(s - m_new)
            l_new = l_run * alpha + p_t
            acc_new = acc * alpha + p_t * v_row[None, :]
            return m_new, l_new, acc_new

        return jax.lax.cond(kept[t], load_and_update, lambda c: c, carry)

    init = (jnp.full((group, 1), NEG_INF, jnp.float32),
            jnp.zeros((group, 1), jnp.float32),
            jnp.zeros((group, d), jnp.float32))
    _, l_run, acc = jax.lax.fori_loop(0, m, attend, init)
    out = acc / jnp.maximum(l_run, 1e-30)
    out = jnp.where(l_run > 0.0, out, 0.0)  # fully-pruned rows emit zeros

    out_ref[0] = out.astype(out_ref.dtype)
    kept_ref[0] = kept.astype(jnp.int8)
    w_ref[0] = jnp.max(w, axis=0)  # group-max slot weight (H2O ranking key)
    thresh_ref[0] = lo


@functools.partial(
    jax.jit,
    static_argnames=("sm_scale", "iters", "hkv", "pooled", "interpret"),
)
def fused_decode_rows(
    qf: jax.Array,  # (B, group, d) — B = batch * kv_heads
    q_even: jax.Array,  # (B, group, d//2)
    q_odd: jax.Array,  # (B, group, d//2)
    packed: jax.Array,  # (B, m, d//2) uint8 — gathered candidate codes
    scale: jax.Array,  # (B, m) f32
    zero: jax.Array,  # (B, m) f32
    valid: jax.Array,  # (B, m) bool/int8
    rows: jax.Array,  # (B, m) i32 cache rows
    p: jax.Array,  # scalar f32
    keys: jax.Array,  # (b, n, hkv, d) or (P, hkv, d) — stays in HBM
    values: jax.Array,  # same layout as keys
    *,
    sm_scale: float,
    iters: int = 24,
    hkv: int,
    pooled: bool,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One launch per call: (out (B, group, d), kept (B, m) int8,
    slot_weights (B, m) f32, threshold (B, group) f32)."""
    interpret = resolve_interpret(interpret)
    B, group, d = qf.shape
    m = packed.shape[1]
    d2 = packed.shape[2]
    valid = valid.astype(jnp.int8)
    p_arr = jnp.broadcast_to(jnp.asarray(p, jnp.float32), (1,))
    return pl.pallas_call(
        functools.partial(_fused_decode_kernel, sm_scale=sm_scale,
                          iters=iters, hkv=hkv, pooled=pooled),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, group, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, group, d2), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, group, d2), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m, d2), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec(memory_space=pltpu.ANY),  # K cache/pool, HBM
            pl.BlockSpec(memory_space=pltpu.ANY),  # V cache/pool, HBM
        ],
        out_specs=[
            pl.BlockSpec((1, group, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1, group), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, group, d), qf.dtype),
            jax.ShapeDtypeStruct((B, m), jnp.int8),
            jax.ShapeDtypeStruct((B, m), jnp.float32),
            jax.ShapeDtypeStruct((B, group), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, 1, d), keys.dtype),
            pltpu.VMEM((1, 1, d), values.dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(qf, q_even, q_odd, packed, scale, zero, valid, rows, p_arr,
      keys, values)
