"""Analytic FLOP / HBM-byte accounting per architecture and input shape.

Why analytic: XLA's ``cost_analysis()`` counts ``while``-loop bodies once,
and every layer stack here is a ``lax.scan`` (plus grad-accumulation and
time-scan loops), so the HLO numbers undercount by the trip counts.  The
roofline's compute/memory terms therefore come from this module — exact
matmul accounting from the configs — while the dry-run's HLO numbers serve
as per-iteration cross-checks and the collective bytes are parsed from the
HLO (scaled by the known loop factors).

Conventions: FLOPs count multiply+add as 2; train = 3x forward (fwd + 2x
bwd); attention for causal training uses the n/2 average context.
"""

from __future__ import annotations

import math

from repro.core.twilight import TwilightConfig
from repro.kernels.fused_decode.kernel import DMA_OVERHEAD_BYTES
from repro.models.common import ModelConfig
from repro.models.model import layer_schedule

BYTES_BF16 = 2
BYTES_F32 = 4


def _mlstm_dims(cfg: ModelConfig) -> tuple[int, int, int]:
    d_inner = int(cfg.xlstm.proj_factor * cfg.d_model)
    d_inner -= d_inner % cfg.n_heads
    return d_inner, cfg.n_heads, d_inner // cfg.n_heads


def param_count_estimate(cfg: ModelConfig) -> int:
    """Total parameters (matches init_params to ~1%)."""
    specs, repeats = layer_schedule(cfg)
    d, dh, hq, hkv = cfg.d_model, cfg.d_head, cfg.n_heads, cfg.n_kv_heads
    total = cfg.padded_vocab * d  # embed
    if not cfg.tie_embeddings:
        total += d * cfg.padded_vocab
    per_period = 0
    for spec in specs:
        if spec.kind == "attn":
            per_period += d * dh * (hq + 2 * hkv) + hq * dh * d
            if spec.has_cross:
                per_period += d * dh * (hq + 2 * hkv) + hq * dh * d
        elif spec.kind == "mamba":
            di = cfg.ssm.expand * d
            dt_rank = cfg.ssm.dt_rank or max(1, -(-d // 16))
            per_period += (d * 2 * di + cfg.ssm.d_conv * di
                           + di * (dt_rank + 2 * cfg.ssm.d_state)
                           + dt_rank * di + di * cfg.ssm.d_state
                           + di * d)
        elif spec.kind == "mlstm":
            di, nh, dhx = _mlstm_dims(cfg)
            per_period += d * 2 * di + 3 * di * di + di * 2 * nh + di * di \
                + di * d + cfg.xlstm.conv_kernel * di
        elif spec.kind == "slstm":
            nh, dhx = cfg.n_heads, d // cfg.n_heads
            per_period += d * 4 * d + 4 * nh * dhx * dhx + d * d
        if spec.kind in ("attn", "mamba"):
            if spec.is_moe:
                moe = cfg.moe
                d_e = moe.d_expert or cfg.d_ff
                per_period += d * moe.n_experts  # router
                per_period += moe.n_experts * 3 * d * d_e
                per_period += moe.n_shared * 3 * d * d_e
            else:
                d_ff = (cfg.moe.dense_d_ff if cfg.moe else 0) or cfg.d_ff
                per_period += 3 * d * d_ff
    total += per_period * repeats
    if cfg.encoder_layers:
        total += cfg.encoder_layers * (
            d * dh * (hq + 2 * hkv) + hq * dh * d + 3 * d * cfg.d_ff)
    return int(total)


def active_param_count(cfg: ModelConfig) -> int:
    """Active params per token (MoE: top-k + shared experts only)."""
    if cfg.moe is None:
        return param_count_estimate(cfg)
    moe = cfg.moe
    d_e = moe.d_expert or cfg.d_ff
    inactive_per_moe_layer = (moe.n_experts - moe.top_k) * 3 * cfg.d_model * d_e
    specs, repeats = layer_schedule(cfg)
    n_moe_layers = sum(s.is_moe for s in specs) * repeats
    return param_count_estimate(cfg) - n_moe_layers * inactive_per_moe_layer


def _layer_flops_fwd(cfg: ModelConfig, spec, tokens: int, ctx: int) -> float:
    """Forward FLOPs of one layer over ``tokens`` with average context ctx."""
    d, dh, hq, hkv = cfg.d_model, cfg.d_head, cfg.n_heads, cfg.n_kv_heads
    f = 0.0
    if spec.kind == "attn":
        f += 2 * tokens * d * dh * (hq + 2 * hkv)  # qkv proj
        f += 2 * tokens * hq * dh * d  # out proj
        f += 2 * 2 * tokens * ctx * hq * dh  # qk^T and pv
        if spec.has_cross:
            f *= 2  # cross-attention of similar size
    elif spec.kind == "mamba":
        di = cfg.ssm.expand * d
        ds = cfg.ssm.d_state
        dt_rank = cfg.ssm.dt_rank or max(1, -(-d // 16))
        f += 2 * tokens * d * 2 * di + 2 * tokens * di * (dt_rank + 2 * ds)
        f += 2 * tokens * dt_rank * di
        f += tokens * di * ds * 6  # discretize + scan update + readout
        f += 2 * tokens * di * d
        f += tokens * di * cfg.ssm.d_conv * 2
    elif spec.kind == "mlstm":
        di, nh, dhx = _mlstm_dims(cfg)
        f += 2 * tokens * d * 2 * di + 3 * 2 * tokens * di * di
        f += tokens * nh * dhx * dhx * 6  # C update + readout
        f += 2 * tokens * di * d
    elif spec.kind == "slstm":
        nh, dhx = cfg.n_heads, d // cfg.n_heads
        f += 2 * tokens * d * 4 * d + 2 * tokens * 4 * nh * dhx * dhx
        f += 2 * tokens * d * d
    if spec.kind in ("attn", "mamba"):
        if spec.is_moe:
            moe = cfg.moe
            d_e = moe.d_expert or cfg.d_ff
            f += 2 * tokens * cfg.d_model * moe.n_experts  # router
            f += 2 * 3 * tokens * moe.top_k * moe.capacity_factor \
                * cfg.d_model * d_e
            f += 2 * 3 * tokens * moe.n_shared * cfg.d_model * d_e
        else:
            d_ff = (cfg.moe.dense_d_ff if cfg.moe else 0) or cfg.d_ff
            f += 2 * 3 * tokens * cfg.d_model * d_ff
    return f


def forward_flops(cfg: ModelConfig, batch: int, seq: int) -> float:
    """Forward pass over (batch, seq) with causal attention (avg ctx = s/2)."""
    specs, repeats = layer_schedule(cfg)
    tokens = batch * seq
    f = sum(_layer_flops_fwd(cfg, s, tokens, seq / 2) for s in specs) * repeats
    f += 2 * tokens * cfg.d_model * cfg.padded_vocab  # lm head
    if cfg.encoder_layers:
        from repro.models.model import LayerSpec
        enc_spec = LayerSpec("attn", False, False)
        f += cfg.encoder_layers * _layer_flops_fwd(cfg, enc_spec, tokens, seq)
    return f


def train_step_flops(cfg: ModelConfig, batch: int, seq: int) -> float:
    return 3.0 * forward_flops(cfg, batch, seq)


# ---------------------------------------------------------------------------
# Twilight attention-operator cost model (per sequence, per attention layer)
# ---------------------------------------------------------------------------

def hierarchical_page_survivors(n_pages: int, page_top_p: float, *,
                                concentration: float = 8.0) -> int:
    """Modeled page-nucleus survivor count under exponential mass decay.

    Sorted descending, per-page attention mass is modeled as an exponential
    profile w_s ∝ exp(-concentration · s / P); the cumulative mass of the
    first s pages is then 1 - exp(-concentration · s / P), so the nucleus
    reaches mass ``page_top_p`` at s = -ln(1 - p) · P / concentration.
    ``concentration=8`` reflects the paper's observation that attention
    mass is heavily page-concentrated at long context (p = 0.9 keeps
    ~29 % of candidate pages — a ~3.5× estimate-stage reduction).
    """
    if page_top_p >= 1.0:
        return n_pages
    frac = -math.log(max(1.0 - page_top_p, 1e-12)) / concentration
    return max(1, min(n_pages, int(math.ceil(frac * n_pages))))


def _hier_live_slots(tw: TwilightConfig, m: int) -> int:
    """Live candidate slots after the page nucleus (== m when disabled)."""
    if tw.page_top_p is None:
        return m
    n_pages = max(1, m // tw.page_size)
    live = hierarchical_page_survivors(n_pages, tw.page_top_p)
    return min(m, live * tw.page_size)


def twilight_stage_flops(tw: TwilightConfig, n: int, hq: int, hkv: int,
                         d: int) -> dict[str, float]:
    """Per-stage FLOPs of one decode step's attention operator.

    ``compact=True``: the estimate runs on the gathered (B0-length)
    candidate buffer, top-p binary-searches B0-length rows, and the final
    attention touches the attended buffer (≤ B0 slots; ``pruned_cap_frac``
    shrinks it toward B1).  ``compact=False`` models the dense-mask
    pipeline the seed shipped: every stage is O(n) regardless of how much
    the selector pruned.
    """
    if not tw.enabled:
        full = 2 * 2 * n * hq * d
        return {"select": 0.0, "estimate": 0.0, "topp": 0.0, "attend": full,
                "total": full}
    b0 = tw.candidate_budget(n)
    sel = 2 * 2 * (n // tw.page_size) * hq * d  # Quest-style page UB scan
    if tw.compact:
        m = min(n, b0)  # index buffer (group-wise budget)
        # Page nucleus: the estimate only scores tokens in surviving pages
        # (the spgemv / fused stage-1 dead-block early-out).
        est_len = _hier_live_slots(tw, m)
        topp_len = m
        # The B1 re-compaction is weight-ranked, so it only runs when the
        # pruner produced weights; base-algorithm-only configs attend over
        # the full candidate buffer.
        attn_len = tw.pruned_capacity(m) if tw.prune_enabled else m
    else:
        est_len = topp_len = attn_len = n
    est = 2 * hq * est_len * d if tw.prune_enabled else 0.0
    topp = hq * topp_len * tw.topp_iters if tw.prune_enabled else 0.0
    attn = 2 * 2 * hq * attn_len * d
    return {"select": float(sel), "estimate": float(est), "topp": float(topp),
            "attend": float(attn), "total": float(sel + est + topp + attn)}


def twilight_stage_bytes(tw: TwilightConfig, n: int, hq: int, hkv: int,
                         d: int, *, bytes_kv: int = BYTES_BF16
                         ) -> dict[str, float]:
    """Per-stage HBM bytes of one decode step's attention operator.

    The compact path's traffic follows the candidate budget: the INT4
    estimate reads d/2+8 bytes for B0 rows and the final K/V gather reads
    the attended buffer only.  The dense path re-reads the full shadow
    cache, n-length f32 weight rows, and streams the whole K/V cache
    behind the mask.
    """
    if not tw.enabled:
        full = 2 * n * hkv * d * bytes_kv
        return {"select": 0.0, "estimate": 0.0, "topp": 0.0, "attend": full,
                "total": full}
    b0 = tw.candidate_budget(n)
    sel = 2 * (n // tw.page_size) * hkv * d * bytes_kv  # Quest page metadata
    if tw.compact:
        m = min(n, b0)
        # Page nucleus: only surviving pages' INT4 rows are read.
        est_len = _hier_live_slots(tw, m)
        topp_len = m
        # Matches _compact_pipeline: re-compaction needs pruner weights.
        attn_len = tw.pruned_capacity(m) if tw.prune_enabled else m
    else:
        est_len = topp_len = attn_len = n
    est = est_len * hkv * (d // 2 + 8) if tw.prune_enabled else 0.0
    topp = topp_len * hq * BYTES_F32 if tw.prune_enabled else 0.0
    attn = 2 * attn_len * hkv * d * bytes_kv
    return {"select": float(sel), "estimate": float(est), "topp": float(topp),
            "attend": float(attn), "total": float(sel + est + topp + attn)}


def serving_pipeline_config() -> TwilightConfig:
    """The serving-shaped Twilight config the traffic benchmarks price.

    One definition so the benchmarks cannot drift from each other: B0 =
    n/4 with the absolute cap lifted (the benchmarks sweep contexts past
    the default cap), compact pipeline, and the staged path's B1
    re-compaction at the engine's serving default ``pruned_cap_frac=0.25``
    (``DecodeEngine`` applies the same default).  Callers wanting the
    dense or uncapped variants ``dataclasses.replace`` from here.
    """
    return TwilightConfig(candidate_frac=0.25, candidate_budget_cap=1 << 30,
                          compact=True, pruned_cap_frac=0.25)


def twilight_pipeline_traffic(tw: TwilightConfig, n: int, hq: int, hkv: int,
                              d: int, *, fused: bool,
                              bytes_kv: int = BYTES_BF16,
                              b1: int | None = None,
                              dma: str | None = None, k: int = 1,
                              mean_run: float = 16.0,
                              union_growth: float = 0.1
                              ) -> dict[str, float]:
    """Per-step HBM bytes **and Pallas launches** of the compact decode
    attention operator — staged pipeline vs the fused single-launch kernel.

    Unlike :func:`twilight_stage_bytes` (which prices each stage's
    *algorithmic* reads), this models the pipeline's real launch structure:

    * staged — three launches (spgemv estimate, top-p search, gathered
      sparse attention).  Every inter-stage buffer round-trips HBM: the
      B0-length f32 score row (estimate → top-p), the normalized weight
      row (top-p → mask/re-compaction), the kept bitmap, the group-max
      slot weights (H2O + B1 ranking), the re-compacted B1 index buffer,
      and the final K/V gather over the ``pruned_capacity`` buffer.
    * fused — one launch (``kernels/fused_decode``).  Scores, weights,
      thresholds, and index buffers never leave VMEM; the only O(B0)
      traffic is the packed INT4 candidate codes in and the mandated
      ``slot_weights``/kept outputs (the serving engine's H2O mass feed);
      final-attention K/V reads cover only the ``b1`` *surviving* rows
      (per-row DMA behind the kept bit).

    ``b1`` defaults to the paper's measured post-top-p budget scale (~2 %
    of the context, Tables 2/5), floored at ``tw.min_candidate``.  Keys:
    ``select`` (identical both ways — outside the fusion boundary),
    ``estimate``, ``interstage``, ``attend``, ``outputs``, ``tail`` (the
    fused region: everything but select), ``total``, ``launches``.

    **DMA granularity** (``dma``): ``None`` models payload bytes only (the
    legacy output, bit-identical).  ``"row"`` / ``"run"`` additionally
    model the *transaction* structure of the fused kernel's survivor
    streaming: each async copy pays ``DMA_OVERHEAD_BYTES`` of descriptor /
    latency cost on top of its payload.  Per-row DMA issues one K and one
    V copy per surviving row; run-coalesced DMA (the block-RLE kernel)
    issues one per contiguous run of ``mean_run`` expected rows.  The
    extra keys are ``attend_txns`` (copies issued for the final K/V
    stream), ``total_eff`` (total + txns·overhead — the effective bytes a
    bandwidth model should price), ``launches_per_token`` and
    ``per_token`` (``total_eff``/token).

    **Multi-token decode** (``k``): one fused launch decodes ``k`` queued
    tokens against the union of their survivor sets (the union grows by
    ``union_growth`` per extra position).  K/V stream once for all ``k``
    accumulators; per-position kept/slot-weight outputs scale with ``k``.
    The staged pipeline has no window path — ``k`` just repeats it.

    **Hierarchical page nucleus** (``tw.page_top_p``): the candidate
    buffer's pages first pass a page-level top-p, so the estimate stage
    only reads the INT4 codes of *surviving* pages
    (:func:`hierarchical_page_survivors` models the survivor count) and
    the post-top-p budget is capped by the live slots.  The extra
    ``page_topp`` key prices the f32 page-weight rows the selector's
    nucleus search reads.  At ``page_top_p=None`` the key is 0.0 and every
    legacy key is bit-identical to the flat model.
    """
    def _finish(row: dict[str, float], txns: float, launches: float,
                kk: int) -> dict[str, float]:
        total_eff = row["total"] + txns * DMA_OVERHEAD_BYTES
        return {**row, "launches": launches, "attend_txns": float(txns),
                "total_eff": float(total_eff),
                "launches_per_token": launches / kk,
                "per_token": total_eff / kk}

    if not (tw.enabled and tw.compact and tw.prune_enabled):
        st = twilight_stage_bytes(tw, n, hq, hkv, d, bytes_kv=bytes_kv)
        st = {kk: v * k for kk, v in st.items()}
        return _finish({**st, "interstage": 0.0, "outputs": 0.0,
                        "page_topp": 0.0,
                        "tail": st["total"] - st["select"]}, 0.0, 1.0 * k, k)
    b0 = tw.candidate_budget(n)
    m = min(n, b0)
    m_live = _hier_live_slots(tw, m)
    page_topp = 0.0
    if tw.page_top_p is not None and tw.page_top_p < 1.0:
        # The selector's page nucleus: softmax + binary search over the
        # per-page score rows (f32, one row per query head).  At p = 1.0
        # the selectors statically skip the nucleus, so the term vanishes
        # and the whole row is bit-identical to ``page_top_p=None``.
        page_topp = float((n // tw.page_size) * hq * BYTES_F32)
    if b1 is None:
        b1 = max(tw.min_candidate, int(0.02 * n))
    b1 = min(b1, m_live)
    sel = 2 * (n // tw.page_size) * hkv * d * bytes_kv
    codes = m_live * hkv * (d // 2 + 8)  # packed nibbles + f32 scale/zero
    score_row = hq * m * BYTES_F32
    out_bytes = hq * d * bytes_kv
    if fused:
        # GQA-group union over the k window positions: K/V stream once.
        b1_k = min(m_live, int(math.ceil(b1 * (1.0 + union_growth * (k - 1)))))
        est = float(codes)
        interstage = 0.0
        attend = 2 * b1_k * hkv * d * bytes_kv
        # kept + slot_weights per position (the H2O mass feed).
        outputs = k * (hkv * m * (1 + BYTES_F32) + out_bytes)
        launches = 1.0
        txns = 0.0
        if dma == "row":
            txns = 2.0 * hkv * b1_k
        elif dma == "run":
            txns = 2.0 * hkv * math.ceil(b1_k / mean_run)
        elif dma is not None:
            raise ValueError(f"dma must be None, 'row' or 'run': {dma!r}")
    else:
        est = float(codes + score_row) * k  # codes in, score row out
        attn_len = tw.pruned_capacity(m)
        # score row back in; weight row out + back in (mask, slot_weights
        # ranking); kept bitmap and slot weights round-trip; the B1 index
        # buffer round-trips when the cap re-compacts.
        interstage = (score_row + 2 * score_row
                      + 2 * hkv * m
                      + 2 * hkv * m * BYTES_F32) * k
        if attn_len < m:
            interstage += 2 * attn_len * hkv * 4 * k
        attend = 2 * attn_len * hkv * d * bytes_kv * k
        outputs = float(out_bytes) * k
        launches = 3.0 * k
        sel = sel * k
        # The staged gather materializes a compacted K/V buffer — its
        # copies are row-granular no matter what the fused kernel does.
        txns = 2.0 * hkv * attn_len * k if dma is not None else 0.0
    tail = est + interstage + attend + outputs
    return _finish(
        {"select": float(sel), "page_topp": page_topp, "estimate": est,
         "interstage": float(interstage), "attend": float(attend),
         "outputs": float(outputs), "tail": float(tail),
         "total": float(sel + page_topp + tail)}, txns, launches, k)


def prefill_attention_traffic(tw: TwilightConfig, s: int, hq: int, hkv: int,
                              d: int, *, n: int | None = None,
                              bytes_kv: int = BYTES_BF16,
                              q_block: int = 256,
                              recent_pages: int = 1) -> dict[str, float]:
    """Per-layer HBM K/V bytes of one sequence's prefill attention.

    Dense flash streams, per ``q_block``-query tile, the tile's whole
    causal context — O(s·n) K/V bytes over the prefill.  The sparse
    prefill kernel (``kernels/sparse_prefill``) instead reads the Quest
    page metadata, runs the per-tile page-nucleus search, and DMAs only
    surviving pages: per tile the live count is the modeled nucleus
    survivor count (:func:`hierarchical_page_survivors` — the same decay
    profile the decode model uses) plus the unconditionally-kept causal
    frontier (``q_block//page_size + 1`` pages a tile's own queries span)
    and ``recent_pages`` window.

    ``n`` is the resident context the queries attend (defaults to ``s``:
    a from-scratch prefill; chunked prefill against a cached prefix passes
    ``n > s``).  Keys: ``dense_attend`` (the dense oracle's bytes),
    ``attend`` (survivor K/V bytes), ``meta`` (page min/max read),
    ``page_topp`` (per-tile f32 page-score rows), ``total`` and
    ``bytes_x`` (dense/total).  With ``tw.prefill_top_p`` None or >= 1.0
    the sparse terms vanish and ``total == dense_attend`` exactly, so
    consumers see bit-identical numbers when the feature is off.
    """
    if n is None:
        n = s
    ps = tw.page_size
    p = tw.prefill_top_p
    nqb = -(-s // q_block)
    off = n - s
    n_pages = -(-n // ps)
    forced = (q_block // ps + 1) + recent_pages
    dense = 0.0
    attend = 0.0
    for i in range(nqb):
        ctx = min(n, off + (i + 1) * q_block)
        dense += 2.0 * ctx * hkv * d * bytes_kv
        cand = -(-ctx // ps)
        live = min(cand, hierarchical_page_survivors(cand, p) + forced) \
            if (p is not None and p < 1.0) else cand
        attend += 2.0 * live * ps * hkv * d * bytes_kv
    if p is None or p >= 1.0:
        return {"dense_attend": dense, "attend": dense, "meta": 0.0,
                "page_topp": 0.0, "total": dense, "bytes_x": 1.0}
    meta = 2.0 * n_pages * hkv * d * bytes_kv
    page_topp = float(nqb * n_pages * hkv * BYTES_F32)
    total = attend + meta + page_topp
    return {"dense_attend": dense, "attend": attend, "meta": meta,
            "page_topp": page_topp, "total": total,
            "bytes_x": dense / total}


def decode_flops(cfg: ModelConfig, batch: int, ctx: int) -> float:
    """One decode step: forward over `batch` tokens with full context `ctx`,
    including the Twilight estimate (q·K̃ over the candidate set) and the
    pruned sparse attention."""
    specs, repeats = layer_schedule(cfg)
    f = sum(_layer_flops_fwd(cfg, s, batch, 0) for s in specs) * repeats
    # Attention context terms, per attention layer.
    n_attn = sum(s.kind == "attn" for s in specs) * repeats
    dh, hq, hkv = cfg.d_head, cfg.n_heads, cfg.n_kv_heads
    stages = twilight_stage_flops(cfg.twilight, ctx, hq, hkv, dh)
    f += n_attn * batch * stages["total"]
    f += 2 * batch * cfg.d_model * cfg.padded_vocab
    return f


def decode_hbm_bytes(cfg: ModelConfig, batch: int, ctx: int) -> float:
    """HBM traffic of one decode step: weights once + per-seq KV traffic."""
    specs, repeats = layer_schedule(cfg)
    n_attn = sum(s.kind == "attn" for s in specs) * repeats
    weights = active_param_count(cfg) * BYTES_BF16
    dh, hq, hkv = cfg.d_head, cfg.n_heads, cfg.n_kv_heads
    stages = twilight_stage_bytes(cfg.twilight, ctx, hq, hkv, dh)
    return weights + batch * n_attn * stages["total"]


def prefill_hbm_bytes(cfg: ModelConfig, batch: int, seq: int) -> float:
    weights = param_count_estimate(cfg) * BYTES_BF16
    acts = 12 * batch * seq * cfg.d_model * cfg.n_layers * BYTES_BF16
    return weights + acts


def train_hbm_bytes(cfg: ModelConfig, batch: int, seq: int) -> float:
    """Params fwd+bwd reads + grad write + Adam read/write + activations."""
    p = param_count_estimate(cfg)
    param_traffic = p * (2 * BYTES_BF16 + BYTES_BF16 + 4 * BYTES_F32)
    acts = 24 * batch * seq * cfg.d_model * cfg.n_layers * BYTES_BF16
    return param_traffic + acts


def model_flops_6nd(cfg: ModelConfig, tokens: int, *, train: bool) -> float:
    """The 6·N·D (train) / 2·N·D (inference) convention, N = active params."""
    n = active_param_count(cfg)
    return (6.0 if train else 2.0) * n * tokens


# ---------------------------------------------------------------------------
# Collective traffic model (per chip, per step)
# ---------------------------------------------------------------------------

def collective_bytes_per_chip(cfg: ModelConfig, kind: str, batch: int,
                              seq: int, *, fsdp: int = 16, tensor: int = 16,
                              seq_parallel: bool | None = None,
                              grad_accum: int = 1) -> dict[str, float]:
    """Analytic per-chip collective bytes for one step on the 16x16 mesh.

    Terms:
      * fsdp_params — all-gather of FSDP-sharded weights before use
        (x2 for train fwd+bwd-recompute) + gradient reduce-scatter.
        Per chip: (param_bytes / tensor) x (fsdp-1)/fsdp per pass.
      * seq_parallel — Megatron-SP gather/scatter of the residual around
        each block (train/prefill with sequence-sharded residuals).
      * inner_allreduce — contractions over tensor-sharded dims (attention
        out-proj, FFN down-proj, SSM x_proj): all-reduce of the block
        output per layer.
    """
    p_bytes = param_count_estimate(cfg) * BYTES_BF16
    b_loc = max(1, batch // fsdp)
    d = cfg.d_model
    specs, repeats = layer_schedule(cfg)
    n_layers = len(specs) * repeats

    passes = 3.0 if kind == "train" else 1.0  # fwd + bwd recompute + grad RS
    # FSDP-sharded weights: the partitioner picks the cheaper of
    # (a) all-gathering the weight shards before each use, or
    # (b) computing partial products and all-reducing the *activations*.
    # Training batches make (a) cheaper; single-token decode makes (b)
    # nearly free.  Weights are re-gathered every grad-accum microstep;
    # activation terms are per *global* batch (microbatching conserves
    # total tokens).
    tokens_loc_all = b_loc * (seq if kind in ("train", "prefill") else 1)
    gather_bytes = passes * (p_bytes / tensor) * (fsdp - 1) / fsdp
    if kind == "train":
        gather_bytes *= grad_accum
    # ~4 sharded matmul outputs per layer of width ~d.
    partial_ar_bytes = passes * n_layers * 4 * tokens_loc_all * d * BYTES_F32 \
        * (fsdp - 1) / fsdp
    fsdp_params = min(gather_bytes, partial_ar_bytes)

    if seq_parallel is None:
        seq_parallel = (kind in ("train", "prefill")
                        and cfg.ssm is None and cfg.xlstm is None
                        and cfg.frontend != "vision")
    sp = 0.0
    ar = 0.0
    act_bytes = b_loc * (seq if kind in ("train", "prefill") else 1) \
        * d * BYTES_BF16
    if seq_parallel and kind in ("train", "prefill"):
        # Megatron-SP: 4 gather/scatter per layer fwd, 4 bwd; these REPLACE
        # the tensor-parallel activation all-reduces.
        per_layer = (8 if kind == "train" else 4) * act_bytes \
            * (tensor - 1) / tensor
        sp = per_layer * n_layers
    else:
        # Plain TP: 2 activation all-reduces per layer (out-proj, ffn-down),
        # x3 for train (fwd + bwd has two).
        ar = n_layers * act_bytes * 2 * (3 if kind == "train" else 1) \
            * (tensor - 1) / tensor

    return {"fsdp_params": fsdp_params, "seq_parallel": sp,
            "inner_allreduce": ar,
            "total": fsdp_params + sp + ar}
