"""INT4 asymmetric quantization of the K cache (§4.2, Appendix B.1).

Per-(token, head) *dynamic* asymmetric quantization over the head dim:
``q = round((k - zero) / scale)`` with ``q in [0, 15]``; two 4-bit codes are
packed per byte along the head dim (even index -> low nibble, odd -> high),
mirroring the paper's interleaved uint8 packing.  Scale/zero are stored per
(token, head) in the cache dtype.

The pure-jnp functions here are the reference; ``repro.kernels.quant`` holds
the Pallas TPU kernel and ``repro.kernels.spgemv`` consumes the packed layout
directly (dequant-in-VMEM).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "QuantizedTensor",
    "quantize_int4",
    "dequantize_int4",
    "packed_nbytes",
]

_LEVELS = 15  # 4-bit unsigned range [0, 15]


class QuantizedTensor(NamedTuple):
    """INT4-packed tensor.  ``packed`` has the quantized axis halved."""

    packed: jax.Array  # uint8 (..., d // 2)
    scale: jax.Array  # f32 (..., 1)
    zero: jax.Array  # f32 (..., 1)

    @property
    def nbytes(self) -> int:
        return int(self.packed.size + self.scale.size * 4 + self.zero.size * 4)


def quantize_int4(x: jax.Array) -> QuantizedTensor:
    """Asymmetric INT4 quantization over the last axis (must be even)."""
    if x.shape[-1] % 2:
        raise ValueError(f"last dim must be even for nibble packing, got {x.shape}")
    xf = x.astype(jnp.float32)
    lo = jnp.min(xf, axis=-1, keepdims=True)
    hi = jnp.max(xf, axis=-1, keepdims=True)
    scale = jnp.maximum((hi - lo) / _LEVELS, 1e-8)
    zero = lo
    codes = jnp.clip(jnp.round((xf - zero) / scale), 0, _LEVELS).astype(jnp.uint8)
    even = codes[..., 0::2]
    odd = codes[..., 1::2]
    packed = (even | (odd << 4)).astype(jnp.uint8)
    return QuantizedTensor(packed=packed, scale=scale, zero=zero)


def dequantize_int4(q: QuantizedTensor, dtype: jnp.dtype = jnp.float32) -> jax.Array:
    """Unpack and dequantize back to ``(..., d)``."""
    even = (q.packed & 0x0F).astype(jnp.float32)
    odd = (q.packed >> 4).astype(jnp.float32)
    d2 = q.packed.shape[-1]
    codes = jnp.stack([even, odd], axis=-1).reshape(*q.packed.shape[:-1], 2 * d2)
    return (codes * q.scale + q.zero).astype(dtype)


def packed_nbytes(shape: tuple[int, ...]) -> int:
    """Bytes used by an INT4 cache of logical shape ``shape`` (last dim = d)."""
    *lead, d = shape
    n = 1
    for s in lead:
        n *= s
    return n * (d // 2) + n * 8  # nibbles + f32 scale/zero
