"""Batched serving with the Twilight engine, in both scheduling modes:

* wave/contiguous (default): fixed waves over per-slot contiguous caches —
  the equivalence oracle;
* continuous/paged (``--paged``): a shared KV page pool with per-request
  page tables; slots retire and admit new requests every decode step, so a
  short request never waits out a long one and memory tracks live tokens.

Works for any assigned architecture (pass --arch):

    PYTHONPATH=src python examples/serve_batch.py --arch deepseek-moe-16b
    PYTHONPATH=src python examples/serve_batch.py --arch qwen2-1.5b --paged
"""

import argparse

import numpy as np

from repro.configs import get_smoke_config
from repro.serving import DecodeEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--paged", action="store_true",
                    help="continuous batching over the shared page pool")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    rng = np.random.default_rng(0)
    engine = DecodeEngine(cfg, batch_size=3, cache_capacity=128,
                          paged=args.paged)

    reqs = []
    for uid in range(args.requests):
        extras = {}
        if cfg.frontend == "audio":
            extras["frames"] = rng.normal(size=(48, cfg.d_model)).astype(
                np.float32)
        elif cfg.frontend == "vision":
            extras["patches"] = rng.normal(
                size=(cfg.n_prefix_tokens, cfg.d_model)).astype(np.float32)
        prompt_len = int(rng.integers(24, 72))
        # Ragged max_new_tokens: the regime where continuous batching wins —
        # a wave would hold every slot for the longest request.
        max_new = int(rng.integers(max(1, args.max_new // 2),
                                   args.max_new + 1))
        reqs.append(Request(
            uid=uid,
            prompt=rng.integers(8, cfg.vocab_size, prompt_len).astype(np.int32),
            max_new_tokens=max_new,
            extras=extras or None,
        ))

    results = engine.generate(reqs)
    mode = "continuous/paged" if args.paged else "wave/contiguous"
    print(f"[{mode}]")
    for r in sorted(results, key=lambda r: r.uid):
        print(f"req {r.uid}: prompt={r.prompt_len:3d} tok, "
              f"generated={r.tokens}, "
              f"mean pruned budget={r.mean_pruned_budget:.1f}")


if __name__ == "__main__":
    main()
