"""Shared helpers for the Pallas kernels."""

from __future__ import annotations

import os

import jax

__all__ = ["default_interpret", "resolve_interpret", "NEG_INF", "pick_block"]

# Large-negative finite stand-in for -inf inside kernels (avoids NaNs from
# exp(-inf - -inf) in the online-softmax recurrences).
NEG_INF = -1e30


def default_interpret() -> bool:
    """Kernels execute in interpret mode everywhere except a real TPU.

    ``REPRO_FORCE_INTERPRET=1`` forces interpretation (useful for debugging
    on TPU); this container is CPU-only so interpret=True is the validated
    path, with TPU lowering exercised structurally by the dry-run.
    """
    if os.environ.get("REPRO_FORCE_INTERPRET"):
        return True
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """Single point of truth for the ``interpret`` tri-state every kernel
    and ops wrapper accepts: None defers to :func:`default_interpret`."""
    return default_interpret() if interpret is None else bool(interpret)


def pick_block(n: int, preferred: int, align: int = 128) -> int:
    """Largest divisor block of ``n`` that is <= preferred, favoring
    MXU/VPU-aligned multiples of ``align`` when possible."""
    if n <= preferred:
        return n
    for cand in range(preferred, 0, -1):
        if n % cand == 0 and (cand % align == 0 or cand < align):
            return cand
    return 1
