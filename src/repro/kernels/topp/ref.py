"""Pure-jnp oracle for the top-p kernel: the core binary search plus the
sort-based Definition 3.3 oracle for semantic checks."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.topp import oracle_topp_mask, topp_threshold


def topp_threshold_rows_ref(
    weights: jax.Array, p: jax.Array, *, iters: int = 24
) -> tuple[jax.Array, jax.Array]:
    thresh = topp_threshold(weights, p, iters=iters)[:, None]
    budget = jnp.sum(weights >= thresh, axis=-1, keepdims=True).astype(jnp.int32)
    return thresh, budget


def topp_budget_oracle(weights: jax.Array, p: float) -> jax.Array:
    return oracle_topp_mask(weights, p).budget[:, None].astype(jnp.int32)
