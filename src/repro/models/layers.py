"""Shared transformer layers: norms, RoPE, GQA attention, MLP, MoE.

Params are plain nested dicts of jax arrays (pytrees) — no framework — so
they stack/scan/shard transparently.  Compute-sensitive reductions run in
f32; params and activations default to the config dtype (bf16).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.attention import mha_attention
from repro.models.common import ModelConfig
from repro.models.flash import flash_attention
from repro.sharding.act import constrain
from repro.sharding.act import get_value as act_get_value

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Primitives
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * (d_in ** -0.5)).astype(dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (b, s, h, d), positions: (b, s) or (s,)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (b, s, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (train path; the decode path lives in serving/decode.py where the
# Twilight pipeline owns the KV cache)
# ---------------------------------------------------------------------------

def attn_init(cfg: ModelConfig, key) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    p: Params = {
        "wq": dense_init(ks[0], d, hq * dh, dtype),
        "wk": dense_init(ks[1], d, hkv * dh, dtype),
        "wv": dense_init(ks[2], d, hkv * dh, dtype),
        "wo": dense_init(ks[3], hq * dh, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * dh,), dtype)
        p["bk"] = jnp.zeros((hkv * dh,), dtype)
        p["bv"] = jnp.zeros((hkv * dh,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dtype)
        p["k_norm"] = jnp.ones((dh,), dtype)
    return p


def attn_qkv(params: Params, cfg: ModelConfig, x: jax.Array,
             positions: jax.Array | None) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Project to (b, s, hq, dh), (b, s, hkv, dh) x2 with bias/qk-norm/RoPE."""
    b, s, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(b, s, cfg.n_heads, cfg.d_head)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    if positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_apply(params: Params, cfg: ModelConfig, x: jax.Array,
               positions: jax.Array, *, causal: bool = True,
               memory: tuple[jax.Array, jax.Array] | None = None) -> jax.Array:
    """Self-attention (memory=None) or cross-attention (memory=(k, v))."""
    b, s, _ = x.shape
    if memory is None:
        q, k, v = attn_qkv(params, cfg, x, positions)
    else:
        q, _, _ = attn_qkv(params, cfg, x, None)
        k, v = memory
        causal = False
    q = constrain(q, "heads")
    k = constrain(k, "kv_heads")
    v = constrain(v, "kv_heads")
    if s >= 256:  # flash path: O(s·d) residuals instead of O(s²) scores
        out = flash_attention(q, k, v, causal, 512, 0)
    else:
        out = mha_attention(q, k, v, causal=causal)
    return out.reshape(b, s, cfg.n_heads * cfg.d_head) @ params["wo"]


def cross_kv(params: Params, cfg: ModelConfig, memory: jax.Array
             ) -> tuple[jax.Array, jax.Array]:
    """Precompute cross-attention K/V from encoder output (b, n, d_model)."""
    b, n, _ = memory.shape
    k = (memory @ params["wk"]).reshape(b, n, cfg.n_kv_heads, cfg.d_head)
    v = (memory @ params["wv"]).reshape(b, n, cfg.n_kv_heads, cfg.d_head)
    if cfg.qkv_bias:
        k = k + params["bk"].reshape(cfg.n_kv_heads, cfg.d_head)
        v = v + params["bv"].reshape(cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm:
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    return k, v


# ---------------------------------------------------------------------------
# Dense FFN (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_init(cfg: ModelConfig, key, d_ff: int | None = None) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "wi": dense_init(ks[0], cfg.d_model, d_ff, dtype),
        "wg": dense_init(ks[1], cfg.d_model, d_ff, dtype),
        "wo": dense_init(ks[2], d_ff, cfg.d_model, dtype),
    }


def mlp_apply(params: Params, x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ params["wg"]) * (x @ params["wi"])) @ params["wo"]


# ---------------------------------------------------------------------------
# Fine-grained MoE (DeepSeek-MoE / Llama-4 / Jamba)
# ---------------------------------------------------------------------------

def moe_init(cfg: ModelConfig, key) -> Params:
    moe = cfg.moe
    assert moe is not None
    dtype = jnp.dtype(cfg.dtype)
    d_e = moe.d_expert or cfg.d_ff
    ks = jax.random.split(key, 5)
    e = moe.n_experts
    p: Params = {
        "router": dense_init(ks[0], cfg.d_model, e, jnp.float32),
        "wi": (jax.random.normal(ks[1], (e, cfg.d_model, d_e), jnp.float32)
               * (cfg.d_model ** -0.5)).astype(dtype),
        "wg": (jax.random.normal(ks[2], (e, cfg.d_model, d_e), jnp.float32)
               * (cfg.d_model ** -0.5)).astype(dtype),
        "wo": (jax.random.normal(ks[3], (e, d_e, cfg.d_model), jnp.float32)
               * (d_e ** -0.5)).astype(dtype),
    }
    if moe.n_shared:
        p["shared"] = mlp_init(cfg, ks[4], d_ff=d_e * moe.n_shared)
    return p


def moe_apply(params: Params, cfg: ModelConfig, x: jax.Array
              ) -> tuple[jax.Array, jax.Array]:
    """Capacity-based expert-parallel dispatch, shard-local.

    Tokens are grouped into ``moe_shards`` dispatch groups aligned with the
    data axis (launch hint via ``repro.sharding.act``; 1 when unsharded);
    each group routes its own tokens to a per-group expert capacity.  All
    gathers/scatters are *batched over the sharded group dim*, so under
    pjit they stay shard-local — the only cross-device traffic is the
    expert-parallel einsum layout (experts over ``model``) and the
    sequence all-gather/reduce-scatter at the block boundary (Megatron-SP
    pattern).  Per-group capacity is the per-device capacity of real
    expert-parallel systems; dropped tokens fall through with zero routed
    contribution (the shared experts remain dense).

    Returns (y, router aux loss).
    """
    moe = cfg.moe
    b, s, d = x.shape
    t = b * s
    e = moe.n_experts
    g = act_get_value("moe_shards", 1)
    if b % g:
        g = 1
    tl = t // g  # tokens per dispatch group

    xt = constrain(x.reshape(g, tl, d), "moe_tokens")
    logits = (xt.astype(jnp.float32) @ params["router"])  # (g, tl, e)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, moe.top_k)  # (g, tl, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # gates (g, tl, e): renormalized top-k probabilities, 0 elsewhere.
    gates = jnp.zeros((g, tl, e), jnp.float32)
    gates = jax.vmap(jax.vmap(lambda gr, i, v: gr.at[i].set(v)))(
        gates, topi, topv)

    cap = int(moe.capacity_factor * moe.top_k * tl / e)
    cap = max(1, min(cap, tl))
    # Per (group, expert): top-C tokens by gate weight (static shapes).
    gv, token_idx = jax.lax.top_k(jnp.swapaxes(gates, 1, 2), cap)  # (g, e, cap)
    xe = jnp.take_along_axis(xt[:, None], token_idx[..., None], axis=2)
    xe = constrain(xe, "moe_dispatch")  # (g, e, cap, d)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, params["wg"]))
    h = h * jnp.einsum("gecd,edf->gecf", xe, params["wi"])
    ye = jnp.einsum("gecf,efd->gecd", h, params["wo"])  # (g, e, cap, d)
    combine = jnp.where(gv > 0, gv, 0.0).astype(x.dtype)
    ye = constrain(ye * combine[..., None], "moe_dispatch")

    # Scatter-add back to tokens, batched over the group dim.
    def combine_group(ye_g, idx_g):
        return jnp.zeros((tl, d), x.dtype).at[idx_g.reshape(-1)].add(
            ye_g.reshape(-1, d))

    yt = constrain(jax.vmap(combine_group)(ye, token_idx), "moe_tokens")

    if "shared" in params:
        yt = yt + mlp_apply(params["shared"], xt)

    # Load-balance aux loss (Switch-style): e * sum(f_i * P_i).
    importance = probs.mean((0, 1))
    load = jnp.zeros((e,), jnp.float32).at[topi.reshape(-1)].add(1.0) \
        / (t * moe.top_k)
    aux = e * jnp.sum(importance * load) * moe.router_aux_weight
    return yt.reshape(b, s, d).astype(x.dtype), aux
