"""Pure-jnp oracle for the block-sparse flash-prefill kernel.

Dense f32 masked attention honoring exactly the kernel's mask algebra:
a query row attends key position ``t`` iff

* ``t <= q_offset + row_position``  (causal, chunk-offset aware),
* ``t < kv_len``                    (resident prefix only), and
* the row's query block kept ``t``'s kv block in the survivor operand.

The kernel's numerics are an online-softmax reordering of this closed
form, so tests compare with fp tolerances; the *mask* semantics — which
(query, key) pairs participate at all — are bit-identical by
construction, which is what the all-dead / all-live / single-page edge
tests pin.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import NEG_INF


def sparse_prefill_ref(
    q: jax.Array,  # (B, nqb, qr, d) — kernel layout, qr = q_block * group
    keys: jax.Array,  # (B, n, d) — pre-gathered, logical key order
    values: jax.Array,  # (B, n, d)
    survivors: jax.Array,  # (B, nqb, nb) bool/int8 — kv-block keep mask
    *,
    kv_len: jax.Array,  # (B,) i32 — resident prefix length per slot
    q_offset: jax.Array,  # (B,) i32 — position of each block's first query
    group: int,
    q_block: int,
    sm_scale: float,
) -> jax.Array:
    """Dense reference: (B, nqb, qr, d) output in the kernel's layout.

    Fully-masked query rows (every key dead or acausal) emit exact zeros,
    matching the kernel's ``l == 0`` contract.
    """
    B, nqb, qr, d = q.shape
    n = keys.shape[1]
    nb = survivors.shape[-1]
    blk = n // nb

    qf = q.astype(jnp.float32)
    kf = keys.astype(jnp.float32)
    vf = values.astype(jnp.float32)

    # Query row r in block qb sits at position q_offset + qb*q_block + r//group.
    qpos = (q_offset[:, None, None]
            + jnp.arange(nqb, dtype=jnp.int32)[None, :, None] * q_block
            + jnp.arange(qr, dtype=jnp.int32)[None, None, :] // group)
    kpos = jnp.arange(n, dtype=jnp.int32)

    scores = jnp.einsum("bqrd,bnd->bqrn", qf, kf) * sm_scale
    keep = (survivors != 0)[:, :, None, :]  # (B, nqb, 1, nb)
    keep = jnp.broadcast_to(
        keep[..., None], (B, nqb, 1, nb, blk)).reshape(B, nqb, 1, n)
    mask = (kpos[None, None, None, :] <= qpos[..., None]) & keep
    mask &= kpos[None, None, None, :] < kv_len[:, None, None, None]

    scores = jnp.where(mask, scores, NEG_INF)
    mx = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.where(mask, jnp.exp(scores - mx), 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bqrn,bnd->bqrd", p, vf) / jnp.maximum(denom, 1e-30)
    return jnp.where(denom > 0.0, out, 0.0)
