"""Token Selectors — the black-box *base algorithms* Twilight wraps (§4.1).

A selector produces a **candidate set** over cached tokens at KV-head
granularity with *group-wise budgets* (Appendix B.2): query-aware selectors
score per query head and rank candidates by the group **max** score, so the
set actually loaded for a KV head is exactly the B0 budget — the
group-level analogue of the union (a token in any group member's top set
has a high group-max score).  The downstream pruner applies true per-query
top-p then unions kept slots, so adaptivity stays per query head.  Two
equivalent representations are exposed:

* ``select(q, ctx, budget) -> bool mask (b, hkv, n)`` — the dense mask API;
  simple, sharding-oblivious, and the test oracle for the compact path.
* ``select_indices(q, ctx, budget) -> (indices (b, hkv, m) i32, valid
  (b, hkv, m) bool)`` — the **compact index API** the production pipeline
  consumes.  ``m`` is a *static* per-selector capacity derived from the
  budget (page-aligned for Quest, lane-rounded otherwise, see
  :func:`index_capacity`), so downstream stages — INT4 score estimation,
  top-p, gathered attention — operate on ``m``-length buffers and their
  cost scales with the candidate budget B0, never the context length n.
  Indices are ascending cache positions; dead slots have ``valid=False``
  and index 0 (safe to gather).  Both representations enumerate the *same*
  candidate set, so compact Select→Prune→Attend matches the dense oracle.

Budgets are *static* Python ints (conservative B0, e.g. seq/4) so all shapes
stay static for TPU; dynamism lives in the *values* of the masks/valid bits,
which is exactly the paper's "dynamic budget as data, not shape" adaptation
for SPMD hardware.  Group-wise budgets make the compact capacity exactly
the (lane-rounded) budget; capacities assume distinct selector scores
(ties at the top-k boundary may otherwise overflow the buffer — ties are
measure-zero for float scores).

Implemented base algorithms (paper §2 baselines):

* :class:`FullSelector`        — keeps everything ("Full+Twilight" row).
* :class:`QuestSelector`       — page-level min/max metadata upper bound [9].
* :class:`DoubleSparsitySelector` — offline label channels, low-rank q·K [12].
* :class:`StreamingSelector`   — attention sinks + recent window [17].
* :class:`H2OSelector`         — accumulated-weight heavy hitters [8].
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Protocol

import jax
import jax.numpy as jnp

from repro.core.topp import masked_softmax, topp_threshold

__all__ = [
    "PageMeta",
    "SelectionContext",
    "TokenSelector",
    "FullSelector",
    "QuestSelector",
    "DoubleSparsitySelector",
    "StreamingSelector",
    "H2OSelector",
    "build_page_meta",
    "calibrate_ds_channels",
    "gather_logical_rows",
    "group_union",
    "topk_mask",
    "page_nucleus_mask",
    "indices_from_mask",
    "indices_to_mask",
    "physical_token_indices",
    "selector_from_name",
]


class PageMeta(NamedTuple):
    """Per-page elementwise min/max of K (Quest metadata).

    Contiguous caches carry batched metadata (b, n_pages, hkv, d); a shared
    page *pool* carries physical-page metadata (num_pages, hkv, d) addressed
    through ``SelectionContext.page_table``.
    """

    kmax: jax.Array  # (b, n_pages, hkv, d) or (num_pages, hkv, d) pooled
    kmin: jax.Array  # same layout as kmax
    page_size: int


class SelectionContext(NamedTuple):
    """Everything a selector may consult.  Unused fields may be None.

    Two cache layouts are supported:

    * contiguous (``page_table is None``): ``keys`` is the per-slot cache
      (b, n, hkv, d) and ``page_meta`` is batched.
    * paged (``page_table`` is the per-slot table (b, max_pages) of physical
      page ids): ``keys`` is the shared pool (num_pages * page_size, hkv, d)
      and ``page_meta`` holds *physical*-page stats.  Selectors gather
      metadata through the table, score **logical** positions, and emit
      logical indices; the pipeline translates them to physical pool rows
      (:func:`physical_token_indices`) for every downstream gather.
    """

    keys: jax.Array | None  # (b, n, hkv, d) or pooled (P, hkv, d)
    page_meta: PageMeta | None
    accum_scores: jax.Array | None  # (b, hkv, n) running attention mass (H2O)
    length: jax.Array | None  # (b,) valid lengths; None = all valid
    # DS label channel indices: (hkv, r) global for contiguous caches, or
    # per-slot (b, hkv, r) for the paged pool (each request calibrated on
    # its own prompt).
    ds_channels: jax.Array | None
    page_table: jax.Array | None = None  # (b, max_pages) i32 physical ids
    # Page-granular accumulated attention mass (H2O in serving): the decode
    # step scatter-adds the pruner's post-top-p weights per page, so H2O
    # ranks *pages* exactly like Quest does — (b, n_pages, hkv) for
    # contiguous caches, (num_pages, hkv) keyed by *physical* page for the
    # shared pool (gathered through ``page_table``).  Token-level
    # ``accum_scores`` takes precedence when both are set.
    page_mass: jax.Array | None = None


class TokenSelector(Protocol):
    name: str

    def select(self, q: jax.Array, ctx: SelectionContext, budget: int) -> jax.Array:
        """q: (b, hq, d) -> bool candidate mask (b, hkv, n)."""
        ...

    def select_indices(
        self, q: jax.Array, ctx: SelectionContext, budget: int
    ) -> tuple[jax.Array, jax.Array]:
        """q: (b, hq, d) -> (indices (b, hkv, m) i32, valid (b, hkv, m))."""
        ...


def _length_mask(n: int, length: jax.Array | None, like: jax.Array) -> jax.Array:
    if length is None:
        return jnp.ones((1, 1, n), bool)
    pos = jnp.arange(n)
    return (pos[None, :] < length[:, None])[:, None, :]


def _ctx_shapes(q: jax.Array, ctx: SelectionContext) -> tuple[int, int, int]:
    """(b, n, hkv) of the *logical* cache view, paged- and pool-aware."""
    b = q.shape[0]
    if ctx.page_table is not None:
        if ctx.page_meta is None:
            raise ValueError("paged selection requires page_meta")
        pm = ctx.page_meta
        return b, ctx.page_table.shape[1] * pm.page_size, pm.kmax.shape[-2]
    if ctx.keys is not None:
        return b, ctx.keys.shape[1], ctx.keys.shape[2]
    if ctx.page_meta is not None:
        pm = ctx.page_meta
        return b, pm.kmax.shape[1] * pm.page_size, pm.kmax.shape[2]
    raise ValueError("selector needs keys, page_meta, or a page table")


def physical_token_indices(page_table: jax.Array, indices: jax.Array,
                           page_size: int) -> jax.Array:
    """Translate logical token indices to physical pool rows.

    page_table: (b, max_pages) i32; indices: (b, hkv, m) logical positions.
    Returns (b, hkv, m) rows into the flattened (num_pages * page_size)
    pool.  Entries pointing at unallocated logical pages resolve to the
    null page (physical 0) — callers gate them with ``valid`` bits.
    """
    b, hkv, m = indices.shape
    page = indices // page_size
    pt = jnp.broadcast_to(page_table[:, None, :],
                          (b, hkv, page_table.shape[1]))
    phys_page = jnp.take_along_axis(pt, page, axis=2)
    return phys_page * page_size + indices % page_size


def gather_logical_rows(pool: jax.Array, page_table: jax.Array,
                        page_size: int) -> jax.Array:
    """Materialize the logical cache view (b, n, hkv, c) from a shared pool
    (num_pages * page_size, hkv, c) through per-slot page tables.  O(n) —
    only for selectors whose scoring is inherently O(n) (Double Sparsity)."""
    b, max_pages = page_table.shape
    rows = (page_table[..., None] * page_size
            + jnp.arange(page_size, dtype=page_table.dtype))
    return jnp.take(pool, rows.reshape(b, max_pages * page_size), axis=0)


def group_union(per_qhead_mask: jax.Array, n_kv_heads: int) -> jax.Array:
    """(b, hq, n) -> (b, hkv, n): union over each GQA group (Appendix B.2)."""
    b, hq, n = per_qhead_mask.shape
    if hq % n_kv_heads:
        raise ValueError(f"hq={hq} not divisible by hkv={n_kv_heads}")
    g = hq // n_kv_heads
    return per_qhead_mask.reshape(b, n_kv_heads, g, n).any(axis=2)


def topk_mask(scores: jax.Array, k: int) -> jax.Array:
    """Boolean mask of the k largest entries along the last axis (ties kept)."""
    n = scores.shape[-1]
    if k >= n:
        return jnp.ones_like(scores, bool)
    kth = jax.lax.top_k(scores, k)[0][..., -1:]
    return scores >= kth


def page_nucleus_mask(scores: jax.Array, participate: jax.Array | None,
                      p: float, iters: int = 24) -> jax.Array:
    """Adaptive page-survivor mask: the *page-level* nucleus pass (§3).

    Softmaxes the page scores (b, hkv, n_pages) over the participating
    pages, binary-searches the top-p threshold (Algorithm 1, same fixed
    trip count as the token stage), and keeps every page whose weight
    meets it.  Non-participating pages get weight 0, so they only survive
    when the threshold degenerates to 0 — which is exactly the two
    intended degenerate cases:

    * the cumulative mass never reaches ``p`` (fp-rounded total < p, or an
      all-zero score row, e.g. H2O before any mass accumulates): keep
      everything, i.e. never prune on a signal that cannot express ``p``;
    * ``p`` is so close to 1 that no positive threshold qualifies.

    Callers intersect the result with their fixed top-k page mask, so the
    static ``B0/page_size`` slot capacity is still the upper bound and the
    nucleus only ever *shrinks* the live count.  Monotone in ``p``: a
    larger ``p`` lowers the threshold and keeps a superset of pages.
    """
    weights = masked_softmax(scores, participate)
    thresh = topp_threshold(weights, p, iters=iters)
    return weights >= thresh[..., None]


def _round_up(x: int, align: int) -> int:
    return -(-x // align) * align


def index_capacity(budget: int, n: int, *, align: int = 128) -> int:
    """Static slot count of a compact index buffer.

    Budgets are group-wise (group-max ranking keeps the candidate count at
    exactly the budget per KV head), lane-rounded for TPU tiling, and
    always capped at ``n`` (the dense representation is never worse).
    """
    return min(n, _round_up(max(1, budget), align))


def indices_from_mask(mask: jax.Array, capacity: int) -> tuple[jax.Array, jax.Array]:
    """Compact a boolean mask (..., n) into (indices (..., m), valid).

    Indices are the True positions in ascending order; surplus slots carry
    ``valid=False`` and index 0 (safe for gathers).  If the mask has more
    than ``capacity`` True entries the *highest* positions are dropped —
    callers size ``capacity`` so this cannot happen for distinct scores.
    """
    n = mask.shape[-1]
    capacity = min(capacity, n)
    # Rank True entries by ascending position: position i scores n - i > 0,
    # False entries score 0, so top_k returns candidates first, in order.
    rank = jnp.where(mask, jnp.arange(n, 0, -1, dtype=jnp.int32), 0)
    vals, idx = jax.lax.top_k(rank, capacity)
    valid = vals > 0
    return jnp.where(valid, idx, 0).astype(jnp.int32), valid


def indices_to_mask(indices: jax.Array, valid: jax.Array, n: int) -> jax.Array:
    """Scatter a compact index buffer (..., m) back to a bool mask (..., n).

    Debug/test helper — the production path never materializes the dense
    mask.  Invalid slots contribute nothing, whatever index they carry.
    """
    onehot = (indices[..., None] == jnp.arange(n, dtype=indices.dtype)
              ) & valid[..., None]
    return onehot.any(axis=-2)


def build_page_meta(keys: jax.Array, page_size: int) -> PageMeta:
    """Compute Quest per-page min/max metadata from K (b, n, hkv, d)."""
    b, n, hkv, d = keys.shape
    if n % page_size:
        raise ValueError(f"n={n} not divisible by page_size={page_size}")
    paged = keys.reshape(b, n // page_size, page_size, hkv, d)
    return PageMeta(kmax=paged.max(axis=2), kmin=paged.min(axis=2), page_size=page_size)


def calibrate_ds_channels(keys: jax.Array, r: int) -> jax.Array:
    """Double Sparsity offline calibration: per KV head, the r channels with
    the largest mean |K| (outlier channels carry most of the q·K signal)."""
    stat = jnp.mean(jnp.abs(keys), axis=(0, 1))  # (hkv, d)
    return jax.lax.top_k(stat, r)[1]  # (hkv, r)


@dataclasses.dataclass(frozen=True)
class FullSelector:
    """Trivial selector: every valid token is a candidate."""

    name: str = "full"

    def select(self, q: jax.Array, ctx: SelectionContext, budget: int) -> jax.Array:
        del budget
        b, n, hkv = _ctx_shapes(q, ctx)
        return jnp.broadcast_to(_length_mask(n, ctx.length, q), (b, hkv, n))

    def select_indices(
        self, q: jax.Array, ctx: SelectionContext, budget: int
    ) -> tuple[jax.Array, jax.Array]:
        del budget  # everything is a candidate: capacity is n by definition
        b, n, hkv = _ctx_shapes(q, ctx)
        idx = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (b, hkv, n))
        valid = jnp.broadcast_to(_length_mask(n, ctx.length, q), (b, hkv, n))
        return idx, valid


@dataclasses.dataclass(frozen=True)
class QuestSelector:
    """Quest [9]: page-granular upper bound max(q*kmax, q*kmin) summed over d.

    ``page_top_p`` turns on the hierarchical page-level nucleus (§3): the
    per-page upper bounds are softmaxed over live pages and only the top-p
    nucleus of pages stays a candidate — intersected with the fixed top-k
    page set, so the compact buffer capacity (``B0/page_size`` page slots)
    is unchanged while the *live* page count adapts to how peaked the page
    distribution is.  ``page_top_p`` of ``None`` or ``1.0`` is the flat
    fixed-B0 selector, bit for bit: at 1.0 the nucleus keeps every candidate
    page by definition, so the intersection is the identity and the branch
    is skipped statically.
    """

    page_top_p: float | None = None
    nucleus_iters: int = 24
    name: str = "quest"

    def _page_mask(self, q: jax.Array, ctx: SelectionContext, budget: int
                   ) -> tuple[jax.Array, int]:
        """Group-budget page mask (b, hkv, n_pages) and the pages budget."""
        if ctx.page_meta is None:
            raise ValueError("QuestSelector requires page metadata")
        pm = ctx.page_meta
        b, hq, d = q.shape
        hkv = pm.kmax.shape[-2]
        group = hq // hkv
        if ctx.page_table is not None:
            # Pooled metadata: gather each slot's physical pages through its
            # table so ranking runs over the logical page axis.  Unallocated
            # entries resolve to the null page — masked below via length.
            kmax_b = jnp.take(pm.kmax, ctx.page_table, axis=0)
            kmin_b = jnp.take(pm.kmin, ctx.page_table, axis=0)
        else:
            kmax_b, kmin_b = pm.kmax, pm.kmin  # (b, n_pages, hkv, d)
        # Upper bound of q·k over each page (Quest): per-channel max of
        # q*kmax and q*kmin, summed over channels.  Each query head scores
        # only its own KV head's pages; pages are ranked by the group-max
        # UB so the per-KV-head selection is exactly the budget
        # (group-wise budgets, Appendix B.2).
        qg = q.reshape(b, hkv, group, 1, d)  # (b, hkv, g, 1, d)
        kmax = jnp.moveaxis(kmax_b, 1, 2)[:, :, None].astype(q.dtype)  # (b,hkv,1,p,d)
        kmin = jnp.moveaxis(kmin_b, 1, 2)[:, :, None].astype(q.dtype)
        ub = jnp.sum(jnp.maximum(qg * kmax, qg * kmin), axis=-1)  # (b,hkv,g,p)
        ub = ub.max(axis=2)  # (b, hkv, n_pages) group-max
        page_live = None
        if ctx.length is not None:
            # Rank only pages with at least one valid token: dead pages carry
            # stale (or, pooled, null-page) metadata and would otherwise
            # waste budget — and break paged/contiguous equivalence.
            n_pages = ub.shape[-1]
            page_live = ((jnp.arange(n_pages) * pm.page_size
                          )[None, :] < ctx.length[:, None])[:, None, :]
            ub = jnp.where(page_live, ub, jnp.finfo(ub.dtype).min)
        pages_budget = max(1, budget // pm.page_size)
        keep = topk_mask(ub, pages_budget)
        if self.page_top_p is not None and self.page_top_p < 1.0:
            keep &= page_nucleus_mask(ub.astype(jnp.float32), page_live,
                                      self.page_top_p, self.nucleus_iters)
        return keep, pages_budget

    def select(self, q: jax.Array, ctx: SelectionContext, budget: int) -> jax.Array:
        pm = ctx.page_meta
        page_mask, _ = self._page_mask(q, ctx, budget)
        n = page_mask.shape[-1] * pm.page_size
        tok = jnp.repeat(page_mask, pm.page_size, axis=-1)
        return tok & _length_mask(n, ctx.length, q)

    def select_indices(
        self, q: jax.Array, ctx: SelectionContext, budget: int
    ) -> tuple[jax.Array, jax.Array]:
        """Page-aligned compact candidates: top pages are compacted at page
        granularity (cheap — n/page_size rank entries), then expanded to
        token indices, so the buffer is a whole number of pages."""
        pm = ctx.page_meta
        page_mask, pages_budget = self._page_mask(q, ctx, budget)
        b, hkv, n_pages = page_mask.shape
        ps = pm.page_size
        cap_pages = min(n_pages, pages_budget)
        pidx, pvalid = indices_from_mask(page_mask, cap_pages)
        offs = jnp.arange(ps, dtype=jnp.int32)
        idx = (pidx[..., None] * ps + offs).reshape(b, hkv, cap_pages * ps)
        valid = jnp.broadcast_to(
            pvalid[..., None], (b, hkv, cap_pages, ps)
        ).reshape(b, hkv, cap_pages * ps)
        if ctx.length is not None:
            valid &= idx < ctx.length[:, None, None]
        return jnp.where(valid, idx, 0), valid


@dataclasses.dataclass(frozen=True)
class DoubleSparsitySelector:
    """Double Sparsity [12]: q·K restricted to offline-calibrated label channels."""

    name: str = "double_sparsity"

    def select(self, q: jax.Array, ctx: SelectionContext, budget: int) -> jax.Array:
        if ctx.keys is None or ctx.ds_channels is None:
            raise ValueError("DoubleSparsitySelector requires keys and ds_channels")
        keys, ch = ctx.keys, ctx.ds_channels  # (b, n, hkv, d), (hkv, r)
        if ctx.page_table is not None:
            # DS scoring is inherently O(n·r): materialize the logical view.
            keys = gather_logical_rows(keys, ctx.page_table,
                                       ctx.page_meta.page_size)
        b, n, hkv, d = keys.shape
        hq = q.shape[1]
        group = hq // hkv
        # Gather label channels.  Channels are global (hkv, r) for the
        # contiguous cache, per-slot (b, hkv, r) for the paged pool (each
        # request calibrated on its own prompt).
        ch_b = ch if ch.ndim == 3 else ch[None]  # (b|1, hkv, r)
        k_lab = jnp.take_along_axis(keys, ch_b[:, None, :, :], axis=-1)  # (b,n,hkv,r)
        qg = q.reshape(b, hkv, group, d)
        q_lab = jnp.take_along_axis(qg, ch_b[:, :, None, :], axis=-1)  # (b,hkv,g,r)
        scores = jnp.einsum("bhgr,bnhr->bhgn", q_lab, k_lab.astype(q.dtype))
        # Group-max ranking keeps the per-KV-head candidate count at
        # exactly the budget (group-wise budgets, Appendix B.2).
        scores = jnp.where(_length_mask(n, ctx.length, q),
                           scores.max(axis=2),
                           jnp.finfo(scores.dtype).min)
        return topk_mask(scores, budget) & _length_mask(n, ctx.length, q)

    def select_indices(
        self, q: jax.Array, ctx: SelectionContext, budget: int
    ) -> tuple[jax.Array, jax.Array]:
        mask = self.select(q, ctx, budget)
        return indices_from_mask(
            mask, index_capacity(budget, mask.shape[-1]))


@dataclasses.dataclass(frozen=True)
class StreamingSelector:
    """StreamingLLM [17]: attention sinks + recent window (query-agnostic)."""

    n_sink: int = 4
    name: str = "streaming"

    def select(self, q: jax.Array, ctx: SelectionContext, budget: int) -> jax.Array:
        b, n, hkv = _ctx_shapes(q, ctx)
        pos = jnp.arange(n)
        length = ctx.length if ctx.length is not None else jnp.full((b,), n)
        recent = budget - self.n_sink
        mask = (pos[None, :] < self.n_sink) | (pos[None, :] >= (length[:, None] - recent))
        mask &= pos[None, :] < length[:, None]
        return jnp.broadcast_to(mask[:, None, :], (b, hkv, n))

    def select_indices(
        self, q: jax.Array, ctx: SelectionContext, budget: int
    ) -> tuple[jax.Array, jax.Array]:
        mask = self.select(q, ctx, budget)
        # Query-agnostic: sinks + recent window never exceed the budget.
        return indices_from_mask(
            mask, index_capacity(budget, mask.shape[-1]))


@dataclasses.dataclass(frozen=True)
class H2OSelector:
    """H2O [8]: heavy hitters by accumulated attention mass + recent window.

    Two granularities, dispatched on what the context carries:

    * token-level ``accum_scores`` (b, hkv, n) — the paper's formulation;
      used by the dense oracle and the raw-pipeline tests.
    * page-level ``page_mass`` — the *serving* formulation: the decode step
      folds the pruner's post-top-p weights into a per-page accumulator
      (per-slot pages for contiguous caches, physical pages for the shared
      pool), and H2O ranks whole pages like Quest does.  This is what makes
      H2O runnable over a paged pool: the pool has nowhere to keep n-length
      per-token state, but per-page mass is O(num_pages) and survives page
      remapping because it is keyed by physical page.

    ``page_top_p`` adds the hierarchical page nucleus on the page-mass path
    (same contract as :class:`QuestSelector`): the recent window is kept
    unconditionally (it outranks any mass in the flat ranking, and a fresh
    page's mass says nothing about the current query), and the nucleus runs
    over the accumulated mass of the *remaining* live pages.  The softmax
    denominator excludes dead pages — including the null page every
    unallocated page-table entry resolves to — and fresh zero-mass pages:
    ``exp(0) = 1`` would hand each of them a full unit of denominator and
    crush the heavy hitters' weights, so a long idle tail would effectively
    disable the nucleus.  The flat top-k ranking is insensitive to all of
    this (rank order ignores the denominator); a nucleus pass is not.
    """

    recent_frac: float = 0.5
    page_top_p: float | None = None
    nucleus_iters: int = 24
    name: str = "h2o"

    def _page_mask(self, q: jax.Array, ctx: SelectionContext, budget: int
                   ) -> tuple[jax.Array, int]:
        """Page-granular H2O mask (b, hkv, n_pages) and the pages budget."""
        if ctx.page_meta is None:
            raise ValueError("page-mass H2O requires page_meta")
        ps = ctx.page_meta.page_size
        mass = ctx.page_mass
        if ctx.page_table is not None:
            # Pooled mass is physical-page keyed: gather each slot's pages
            # through its table so ranking runs over the logical page axis.
            mass = jnp.take(mass, ctx.page_table, axis=0)  # (b, mp, hkv)
        mass = jnp.moveaxis(mass, 1, 2)  # (b, hkv, n_pages)
        b, hkv, n_pages = mass.shape
        pages_budget = max(1, budget // ps)
        n_recent = max(1, int(pages_budget * self.recent_frac))
        length = (ctx.length if ctx.length is not None
                  else jnp.full((b,), n_pages * ps))
        n_live = -(-length // ps)  # (b,) pages with >= 1 valid token
        page = jnp.arange(n_pages)
        live = page[None, :] < n_live[:, None]  # (b, n_pages)
        recent = live & (page[None, :] >= (n_live - n_recent)[:, None])
        # Rank-based selection, NOT a >= threshold mask: fresh pages all
        # carry mass 0, so early decode steps are guaranteed ties — a
        # threshold mask would then select every live page and downstream
        # capacity truncation (which keeps the LOWEST positions) would
        # silently drop the recent window, including the current token's
        # page.  Instead the recent window outranks any mass and the
        # remaining slots go to the highest-mass pages, ties resolving
        # deterministically toward older pages (stable sort — the
        # attention-sink end, matching the streaming intuition).
        neg = jnp.finfo(jnp.float32).min
        prio = jnp.where(live[:, None, :], mass, neg)
        prio = jnp.where(recent[:, None, :], jnp.inf, prio)
        order = jnp.argsort(prio, axis=-1, stable=True, descending=True)
        keep = order[..., :min(pages_budget, n_pages)]
        mask = jnp.zeros((b, hkv, n_pages), bool)
        b_idx = jnp.arange(b)[:, None, None]
        h_idx = jnp.arange(hkv)[None, :, None]
        mask = mask.at[b_idx, h_idx, keep].set(True)
        mask &= live[:, None, :]
        if self.page_top_p is not None and self.page_top_p < 1.0:
            # Hierarchical nucleus over accumulated mass.  Participation
            # excludes the recent window (kept unconditionally below), dead
            # pages (incl. the null page unallocated table entries resolve
            # to), and fresh zero-mass pages — see the class docstring.
            participate = (live & ~recent)[:, None, :] & (mass > 0.0)
            nucleus = page_nucleus_mask(mass.astype(jnp.float32), participate,
                                        self.page_top_p, self.nucleus_iters)
            mask &= recent[:, None, :] | nucleus
        return mask, pages_budget

    def _select_pages(self, q: jax.Array, ctx: SelectionContext,
                      budget: int) -> jax.Array:
        pm = ctx.page_meta
        page_mask, _ = self._page_mask(q, ctx, budget)
        n = page_mask.shape[-1] * pm.page_size
        tok = jnp.repeat(page_mask, pm.page_size, axis=-1)
        return tok & _length_mask(n, ctx.length, q)

    def _select_indices_pages(
        self, q: jax.Array, ctx: SelectionContext, budget: int
    ) -> tuple[jax.Array, jax.Array]:
        """Page-aligned compact candidates, exactly like Quest's."""
        pm = ctx.page_meta
        page_mask, pages_budget = self._page_mask(q, ctx, budget)
        b, hkv, n_pages = page_mask.shape
        ps = pm.page_size
        cap_pages = min(n_pages, pages_budget)
        pidx, pvalid = indices_from_mask(page_mask, cap_pages)
        offs = jnp.arange(ps, dtype=jnp.int32)
        idx = (pidx[..., None] * ps + offs).reshape(b, hkv, cap_pages * ps)
        valid = jnp.broadcast_to(
            pvalid[..., None], (b, hkv, cap_pages, ps)
        ).reshape(b, hkv, cap_pages * ps)
        if ctx.length is not None:
            valid &= idx < ctx.length[:, None, None]
        return jnp.where(valid, idx, 0), valid

    def select(self, q: jax.Array, ctx: SelectionContext, budget: int) -> jax.Array:
        if ctx.accum_scores is None:
            if ctx.page_mass is not None:
                return self._select_pages(q, ctx, budget)
            raise ValueError("H2OSelector requires accum_scores or page_mass")
        b, hkv, n = ctx.accum_scores.shape
        n_recent = int(budget * self.recent_frac)
        n_heavy = budget - n_recent
        pos = jnp.arange(n)
        length = ctx.length if ctx.length is not None else jnp.full((b,), n)
        recent = (pos[None, :] >= (length[:, None] - n_recent)) & (
            pos[None, :] < length[:, None]
        )
        valid = _length_mask(n, ctx.length, q)
        scores = jnp.where(valid, ctx.accum_scores, jnp.finfo(jnp.float32).min)
        heavy = topk_mask(scores, n_heavy)
        return (heavy | recent[:, None, :]) & valid

    def select_indices(
        self, q: jax.Array, ctx: SelectionContext, budget: int
    ) -> tuple[jax.Array, jax.Array]:
        if ctx.accum_scores is None and ctx.page_mass is not None:
            return self._select_indices_pages(q, ctx, budget)
        mask = self.select(q, ctx, budget)
        # Heavy hitters are scored per KV head (no group union): heavy +
        # recent together stay within the budget.
        return indices_from_mask(
            mask, index_capacity(budget, mask.shape[-1]))


_REGISTRY = {
    "full": FullSelector,
    "quest": QuestSelector,
    "double_sparsity": DoubleSparsitySelector,
    "ds": DoubleSparsitySelector,
    "streaming": StreamingSelector,
    "h2o": H2OSelector,
}


def selector_from_name(name: str, **kwargs) -> TokenSelector:
    try:
        return _REGISTRY[name](**kwargs)
    except KeyError:
        raise ValueError(f"unknown selector {name!r}; have {sorted(_REGISTRY)}") from None
