"""Hierarchical page→token top-p: the adaptive page nucleus.

Contracts pinned here, mirroring how the feature is layered:

* selector — ``page_top_p=1.0`` is *bit-for-bit* the fixed-B0 selector
  (the nucleus branch is statically skipped, so the reduction is by
  construction); survivor sets grow monotonically in ``page_top_p``; the
  H2O nucleus never prunes the recent window and excludes zero-mass pages
  from the softmax denominator.
* pipeline — hierarchical fused output is allclose-exact vs the staged
  oracle for quest and h2o at ragged lengths, contiguous and paged.
* kernel — the fused stage-1 page early-out matches the pure-jnp
  reference on the degenerate survivor patterns (all pages dead, all
  live, a single live page).
* cost model — legacy keys bit-identical when the nucleus is off; the
  modeled estimate-stage reduction meets the ≥3× acceptance bar at 64k
  context and ``page_top_p=0.9``; survivor counts are monotone in p.
* telemetry — the run-stats vector's live-pages section is exact
  arithmetic and zero when no candidate validity is supplied.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SelectionContext,
    build_page_meta,
    quantize_int4,
    twilight_decode_attention,
)
from repro.core import runs as runs_lib
from repro.core.selectors import H2OSelector
from repro.kernels.fused_decode.ops import fused_prune_attend
from repro.kernels.fused_decode.ref import (
    fused_prune_attend_ref,
    page_survivor_blocks,
)
from tests.test_fused_decode import _cfg, _ctx, _setup
from tests.test_paged_cache import _paged_fixture

HIER_SELECTORS = ("quest", "h2o")


def _hcfg(selector, fused="staged", page_top_p=None, **kw):
    return dataclasses.replace(_cfg(selector, fused, **kw),
                               page_top_p=page_top_p)


def _h2o_page_ctx(ctx):
    """Swap token-level ``accum_scores`` for page-granular mass.

    Token-level ``accum_scores`` takes precedence in the context and routes
    H2O down the paper-formulation path, which has no page nucleus; the
    nucleus lives on the serving-formulation page-mass path.  Derive the
    page mass from the same scores so the fixture's data still drives the
    ranking.
    """
    acc = ctx.accum_scores  # (b, hkv, n)
    ps = ctx.page_meta.page_size
    b, hkv, n = acc.shape
    mass = acc.reshape(b, hkv, n // ps, ps).sum(-1)  # (b, hkv, n_pages)
    if ctx.page_table is not None:
        # Pool mass is keyed by *physical* page: scatter through the table.
        pt = np.asarray(ctx.page_table)
        num_pages = ctx.page_meta.kmax.shape[0]
        pool = np.zeros((num_pages, hkv), np.float32)
        m = np.asarray(jnp.moveaxis(mass, 1, 2))  # (b, n_pages, hkv)
        for bb in range(pt.shape[0]):
            for p in range(pt.shape[1]):
                pool[pt[bb, p]] = m[bb, p]
        page_mass = jnp.asarray(pool)
    else:
        page_mass = jnp.moveaxis(mass, 1, 2)  # (b, n_pages, hkv)
    return ctx._replace(accum_scores=None, page_mass=page_mass)


def _hier_ctx(selector, ctx):
    return _h2o_page_ctx(ctx) if selector == "h2o" else ctx


# ---------------------------------------------------------------------------
# Selector level: p = 1.0 reduction and monotonicity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("selector", HIER_SELECTORS)
@pytest.mark.parametrize("ragged", [False, True])
def test_page_top_p_one_is_fixed_b0(rng, selector, ragged):
    """page_top_p=1.0 must be *bit-for-bit* the flat selector: the nucleus
    branch is statically skipped, so masks, indices, and weights agree
    exactly — not just allclose."""
    q, K, V = _setup(rng)
    length = jnp.asarray([512, 300]) if ragged else None
    ctx = _hier_ctx(selector, _ctx(rng, K, length=length))
    flat = twilight_decode_attention(
        q, K, V, _hcfg(selector, "staged", None), ctx=ctx, length=length)
    one = twilight_decode_attention(
        q, K, V, _hcfg(selector, "staged", 1.0), ctx=ctx, length=length)
    np.testing.assert_array_equal(np.asarray(flat.indices),
                                  np.asarray(one.indices))
    np.testing.assert_array_equal(np.asarray(flat.candidate_valid),
                                  np.asarray(one.candidate_valid))
    np.testing.assert_array_equal(np.asarray(flat.pruned_valid),
                                  np.asarray(one.pruned_valid))
    np.testing.assert_array_equal(np.asarray(flat.out), np.asarray(one.out))


@pytest.mark.parametrize("selector", HIER_SELECTORS)
def test_page_top_p_one_is_fixed_b0_paged(rng, selector):
    fx = _paged_fixture(rng)
    length = jnp.asarray([256, 180])
    kw = dict(candidate_frac=0.5, min_candidate=64)
    ctx = _hier_ctx(selector, fx["ctx_paged"](length))
    flat = twilight_decode_attention(
        fx["q"], fx["k_pool"], fx["v_pool"],
        _hcfg(selector, "staged", None, **kw),
        ctx=ctx, qkeys=fx["qkeys_pool"], length=length)
    one = twilight_decode_attention(
        fx["q"], fx["k_pool"], fx["v_pool"],
        _hcfg(selector, "staged", 1.0, **kw),
        ctx=ctx, qkeys=fx["qkeys_pool"], length=length)
    np.testing.assert_array_equal(np.asarray(flat.indices),
                                  np.asarray(one.indices))
    np.testing.assert_array_equal(np.asarray(flat.candidate_valid),
                                  np.asarray(one.candidate_valid))
    np.testing.assert_array_equal(np.asarray(flat.out), np.asarray(one.out))


@pytest.mark.parametrize("selector", HIER_SELECTORS)
def test_survivors_monotone_in_page_top_p(rng, selector):
    """A larger nucleus mass can only ADD pages: the candidate survivor
    count is non-decreasing in page_top_p (up to the fixed-B0 cap at 1.0)."""
    q, K, V = _setup(rng)
    length = jnp.asarray([512, 300])
    ctx = _hier_ctx(selector, _ctx(rng, K, length=length))
    prev = None
    for p in (0.5, 0.8, 0.95, 1.0):
        out = twilight_decode_attention(
            q, K, V, _hcfg(selector, "staged", p), ctx=ctx, length=length)
        count = np.asarray(out.candidate_valid).sum()
        if prev is not None:
            assert count >= prev, f"survivors shrank at p={p}"
        prev = count


def test_h2o_nucleus_keeps_recent_and_heavy(rng):
    """The H2O page nucleus (a) never prunes the recent window, and (b)
    with mass concentrated on a few pages prunes the zero-mass rest —
    which requires the zero-mass pages to be excluded from the softmax
    denominator (exp(0)=1 terms from a dozen empty pages would flatten
    the heavy pages' weights toward zero and keep everything)."""
    b, n, hkv, d, page = 1, 256, 1, 64, 16
    n_pages = n // page
    K = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    # All mass on pages 2 and 5; every other page exactly zero.
    mass = np.zeros((b, n_pages, hkv), np.float32)
    mass[:, 2] = 4.0
    mass[:, 5] = 2.0
    sel = H2OSelector(recent_frac=0.25, page_top_p=0.9)
    ctx = SelectionContext(keys=K, page_meta=build_page_meta(K, page),
                           accum_scores=None, length=jnp.asarray([n]),
                           ds_channels=None, page_mass=jnp.asarray(mass))
    mask = np.asarray(sel.select(
        jnp.zeros((b, hkv * 8, d), jnp.float32), ctx, budget=192))
    pages = mask.reshape(b, hkv, n_pages, page).any(-1)[0, 0]
    assert pages[2] and pages[5], "heavy-hitter pages must survive"
    # budget 192 -> 12 pages, recent_frac 0.25 -> the 3 newest pages.
    assert pages[n_pages - 3:].all(), "recent window must survive"
    # The nucleus must actually prune: zero-mass, non-recent pages die.
    dead = [i for i in range(n_pages - 3) if i not in (2, 5)]
    assert not pages[dead].any()


# ---------------------------------------------------------------------------
# Pipeline level: hierarchical fused vs staged oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("selector", HIER_SELECTORS)
@pytest.mark.parametrize("ragged", [False, True])
def test_hier_fused_matches_staged(rng, selector, ragged):
    from tests.test_fused_decode import _assert_fused_matches_staged
    q, K, V = _setup(rng)
    length = jnp.asarray([512, 300]) if ragged else None
    ctx = _hier_ctx(selector, _ctx(rng, K, length=length))
    staged = twilight_decode_attention(
        q, K, V, _hcfg(selector, "staged", 0.85), ctx=ctx, length=length)
    fused = twilight_decode_attention(
        q, K, V, _hcfg(selector, "fused", 0.85), ctx=ctx, length=length)
    _assert_fused_matches_staged(fused, staged)


@pytest.mark.parametrize("selector", HIER_SELECTORS)
def test_hier_fused_matches_staged_paged(rng, selector):
    from tests.test_fused_decode import _assert_fused_matches_staged
    fx = _paged_fixture(rng)
    length = jnp.asarray([256, 180])
    kw = dict(candidate_frac=0.5, min_candidate=64)
    ctx = _hier_ctx(selector, fx["ctx_paged"](length))
    staged = twilight_decode_attention(
        fx["q"], fx["k_pool"], fx["v_pool"],
        _hcfg(selector, "staged", 0.85, **kw),
        ctx=ctx, qkeys=fx["qkeys_pool"], length=length)
    fused = twilight_decode_attention(
        fx["q"], fx["k_pool"], fx["v_pool"],
        _hcfg(selector, "fused", 0.85, **kw),
        ctx=ctx, qkeys=fx["qkeys_pool"], length=length)
    _assert_fused_matches_staged(fused, staged)


# ---------------------------------------------------------------------------
# Kernel level: page early-out vs the reference on degenerate patterns
# ---------------------------------------------------------------------------

def _op_setup(rng, b=2, hq=8, hkv=2, n=256, m=128, d=64):
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    K = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(b, n, hkv, d)), jnp.float32)
    idx = jnp.broadcast_to(jnp.arange(m), (b, hkv, m)).astype(jnp.int32)
    return q, K, V, idx


@pytest.mark.parametrize("pattern", ["all_dead", "all_live", "single_page"])
def test_hier_kernel_matches_ref_patterns(rng, pattern):
    """Stage-1 page early-out vs the reference, on the survivor patterns
    where the cond either never or always takes the live branch."""
    page = 16
    q, K, V, idx = _op_setup(rng)
    b, hkv, m = idx.shape
    valid = np.zeros((b, hkv, m), bool)
    if pattern == "all_live":
        valid[:] = True
    elif pattern == "single_page":
        valid[:, :, 3 * page:4 * page] = True
    valid = jnp.asarray(valid)
    qkeys = quantize_int4(K)
    got = fused_prune_attend(q, idx, valid, K, V, qkeys, p=0.9,
                             page_size=page, hierarchical=True)
    want = fused_prune_attend_ref(q, idx, valid, K, V, qkeys, p=0.9,
                                  page_size=page)
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
    np.testing.assert_allclose(np.asarray(got[2]), np.asarray(want[2]),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               rtol=1e-4, atol=1e-4)
    if pattern == "all_dead":
        # Fully dead buffer: exact zeros everywhere, no DMA issued.
        np.testing.assert_array_equal(np.asarray(got[0]), 0.0)
        np.testing.assert_array_equal(np.asarray(got[2]), 0.0)


def test_hier_kernel_flat_equivalence(rng):
    """hierarchical=True with an arbitrary (page-aligned) survivor set is
    numerically identical to the flat stage 1 — the blocked cond loop is a
    pure compute-elision, never a semantics change."""
    page = 16
    q, K, V, idx = _op_setup(rng)
    b, hkv, m = idx.shape
    valid = np.ones((b, hkv, m), bool)
    valid[:, :, 1 * page:3 * page] = False
    valid[:, 1:, 5 * page:6 * page] = False
    valid = jnp.asarray(valid)
    qkeys = quantize_int4(K)
    flat = fused_prune_attend(q, idx, valid, K, V, qkeys, p=0.9,
                              page_size=page, hierarchical=False)
    hier = fused_prune_attend(q, idx, valid, K, V, qkeys, p=0.9,
                              page_size=page, hierarchical=True)
    np.testing.assert_array_equal(np.asarray(flat[1]), np.asarray(hier[1]))
    np.testing.assert_allclose(np.asarray(flat[0]), np.asarray(hier[0]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(flat[2]), np.asarray(hier[2]),
                               rtol=1e-5, atol=1e-7)


def test_page_survivor_blocks_derivation():
    m, page = 64, 16
    valid = np.zeros((1, 1, m), bool)
    valid[0, 0, 17] = True  # one live slot in page 1
    out = np.asarray(page_survivor_blocks(jnp.asarray(valid), m, page))
    np.testing.assert_array_equal(out[0, 0], [False, True, False, False])


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------

def test_cost_model_off_is_bit_identical():
    """page_top_p=None and page_top_p=1.0 must price exactly like the flat
    pipeline — every shared key equal, page_topp = 0."""
    from repro.analysis.costs import (
        serving_pipeline_config,
        twilight_pipeline_traffic,
    )
    tw = serving_pipeline_config()
    for n in (8192, 65536):
        for fused in (False, True):
            base = twilight_pipeline_traffic(tw, n, 32, 8, 128, fused=fused)
            one = twilight_pipeline_traffic(
                dataclasses.replace(tw, page_top_p=1.0), n, 32, 8, 128,
                fused=fused)
            assert base["page_topp"] == 0.0 and one["page_topp"] == 0.0
            assert base == one


def test_cost_model_estimate_reduction_meets_bar():
    """Acceptance: ≥3× modeled estimate-stage bytes at 64k, p_page=0.9."""
    from repro.analysis.costs import (
        serving_pipeline_config,
        twilight_pipeline_traffic,
    )
    tw = serving_pipeline_config()
    flat = twilight_pipeline_traffic(tw, 65536, 32, 8, 128, fused=True)
    hier = twilight_pipeline_traffic(
        dataclasses.replace(tw, page_top_p=0.9), 65536, 32, 8, 128,
        fused=True)
    assert flat["estimate"] / hier["estimate"] >= 3.0
    assert hier["total"] < flat["total"]  # net win despite page_topp term


def test_cost_model_survivors_monotone():
    from repro.analysis.costs import hierarchical_page_survivors
    prev = 0
    for p in (0.5, 0.8, 0.9, 0.95, 0.99, 1.0):
        s = hierarchical_page_survivors(256, p)
        assert s >= prev
        prev = s
    assert hierarchical_page_survivors(256, 1.0) == 256
    assert 1 <= hierarchical_page_survivors(256, 0.5) < 256


# ---------------------------------------------------------------------------
# Telemetry
# ---------------------------------------------------------------------------

def test_run_stats_live_pages_arithmetic():
    m, page = 64, 16
    kept = np.zeros((1, 1, m), bool)
    kept[0, 0, :4] = True
    idx = jnp.broadcast_to(jnp.arange(m), (1, 1, m)).astype(jnp.int32)
    cand = np.zeros((1, 1, m), bool)
    cand[0, 0, 0:page] = True
    cand[0, 0, 2 * page:3 * page] = True  # 2 live pages -> log2 bucket 1
    vec = np.asarray(runs_lib.run_length_stats(
        jnp.asarray(kept), idx, page, m // page,
        cand_valid=jnp.asarray(cand)))
    assert vec.shape == (runs_lib.RUN_STATS_LEN,)
    B = runs_lib.RUN_HIST_BUCKETS
    live_hist = vec[B + 3:2 * B + 3]
    np.testing.assert_array_equal(live_hist,
                                  [0, 1, 0, 0, 0, 0, 0, 0])
    assert vec[2 * B + 3] == 2.0  # cand_pages
    assert vec[2 * B + 4] == 2.0 * page  # cand_rows
    # Without cand_valid the hierarchical section is exactly zero.
    vec0 = np.asarray(runs_lib.run_length_stats(
        jnp.asarray(kept), idx, page, m // page))
    np.testing.assert_array_equal(vec0[B + 3:], 0.0)
    np.testing.assert_array_equal(vec0[:B + 3], vec[:B + 3])
    summ = runs_lib.summarize_run_stats(vec, steps=1)
    assert summ["cand_pages_per_step"] == 2.0
    assert summ["cand_rows_per_step"] == 32.0
    assert summ["live_page_hist"][1] == 1
