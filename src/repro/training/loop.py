"""Training substrate: loss, train_step factory, and a host loop.

``make_train_step`` returns a pure (params, opt_state, batch) -> ... function
suitable for jit/pjit — the dry-run lowers exactly this function on the
production mesh.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp

from repro.models import forward
from repro.models.common import ModelConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule

Tree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    adamw: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    remat: bool = True
    z_loss: float = 1e-4  # logit regularizer, stabilizes bf16 training
    # Gradient accumulation: the global batch is split into this many
    # microbatches (strided over the batch dim so each microbatch stays
    # evenly sharded); grads accumulate in f32.  The memory lever for the
    # 100B+ archs whose activations cannot fit at full batch.
    grad_accum: int = 1


def loss_fn(params: Tree, cfg: ModelConfig, batch: dict[str, jax.Array],
            *, remat: bool, z_loss: float) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Next-token CE (+MoE aux, +z-loss).  Labels < 0 are ignored (used by
    the needle benchmark to supervise only the retrieval positions)."""
    logits, aux = forward(params, cfg, batch, remat=remat)
    labels = batch["labels"]
    if cfg.frontend == "vision":
        # Prefix patches were prepended; score text positions only.
        logits = logits[:, cfg.n_prefix_tokens:]
    logits = logits.astype(jnp.float32)
    valid = labels >= 0
    safe_labels = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe_labels[..., None], axis=-1)[..., 0] \
        - logz
    denom = jnp.maximum(valid.sum(), 1)
    ce = -jnp.where(valid, ll, 0.0).sum() / denom
    zl = z_loss * jnp.square(jnp.where(valid, logz, 0.0)).sum() / denom
    total = ce + aux + zl
    metrics = {"loss": total, "ce": ce, "moe_aux": aux,
               "ppl": jnp.exp(jnp.minimum(ce, 20.0))}
    return total, metrics


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig
                    ) -> Callable[[Tree, Tree, dict[str, jax.Array]],
                                  tuple[Tree, Tree, dict[str, jax.Array]]]:
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        lr = cosine_schedule(opt_state["step"], tcfg.warmup_steps,
                             tcfg.total_steps, tcfg.peak_lr)
        k = tcfg.grad_accum
        if k <= 1:
            (_, metrics), grads = grad_fn(params, cfg, batch,
                                          remat=tcfg.remat, z_loss=tcfg.z_loss)
        else:
            # Strided microbatches: row i goes to microbatch i % k, so each
            # microbatch keeps the full data-parallel sharding.
            def split(x):
                b = x.shape[0]
                return jnp.swapaxes(
                    x.reshape(b // k, k, *x.shape[1:]), 0, 1)

            micro = jax.tree_util.tree_map(split, batch)

            def micro_step(gsum, mb):
                (_, metrics), grads = grad_fn(params, cfg, mb,
                                              remat=tcfg.remat,
                                              z_loss=tcfg.z_loss)
                gsum = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), gsum, grads)
                return gsum, metrics

            gsum0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            gsum, metrics_stack = jax.lax.scan(micro_step, gsum0, micro)
            grads = jax.tree_util.tree_map(lambda g: g / k, gsum)
            metrics = jax.tree_util.tree_map(
                lambda m: m.mean(), metrics_stack)
        params, opt_state, opt_metrics = adamw_update(
            tcfg.adamw, grads, opt_state, params, lr)
        return params, opt_state, {**metrics, **opt_metrics}

    return train_step


def train_loop(params: Tree, cfg: ModelConfig, tcfg: TrainConfig,
               batches: Iterator[dict], *, log_every: int = 10,
               jit: bool = True):
    """Single-host loop used by the examples; returns (params, history)."""
    opt_state = adamw_init(params)
    step_fn = make_train_step(cfg, tcfg)
    if jit:
        step_fn = jax.jit(step_fn)
    history = []
    t0 = time.time()
    for i, host_batch in enumerate(batches):
        batch = {k: jnp.asarray(v) for k, v in host_batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % log_every == 0 or i == tcfg.total_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i
            m["elapsed_s"] = time.time() - t0
            history.append(m)
            print(f"step {i:5d}  loss {m['loss']:.4f}  ppl {m['ppl']:.2f}  "
                  f"gnorm {m['grad_norm']:.2f}  lr {m['lr']:.2e}")
    return params, history
