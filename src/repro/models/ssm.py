"""Mamba-1 selective SSM block (Jamba's recurrent mixer).

Train path: selective scan over time via ``jax.lax.scan`` (O(1)-memory,
O(s) sequential) with an optional chunked ``associative_scan`` mode that
trades VMEM/HBM for parallelism — the hillclimb knob for the hybrid arch.
Decode path: single-step state update (O(1) per token — why Jamba runs
`long_500k` natively).

State per layer: conv tail (b, d_conv-1, d_inner) + SSM state
(b, d_inner, d_state).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.sharding.act import constrain

Params = dict[str, Any]


def _dims(cfg: ModelConfig) -> tuple[int, int, int, int]:
    ssm = cfg.ssm
    assert ssm is not None
    d_inner = ssm.expand * cfg.d_model
    dt_rank = ssm.dt_rank or max(1, -(-cfg.d_model // 16))
    return d_inner, ssm.d_state, ssm.d_conv, dt_rank


def mamba_init(cfg: ModelConfig, key) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    d_inner, d_state, d_conv, dt_rank = _dims(cfg)
    ks = jax.random.split(key, 6)
    scale = cfg.d_model ** -0.5
    p: Params = {
        "in_proj": (jax.random.normal(ks[0], (cfg.d_model, 2 * d_inner), jnp.float32)
                    * scale).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, d_inner), jnp.float32)
                   * (d_conv ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": (jax.random.normal(ks[2], (d_inner, dt_rank + 2 * d_state),
                                     jnp.float32) * (d_inner ** -0.5)).astype(dtype),
        "dt_proj": (jax.random.normal(ks[3], (dt_rank, d_inner), jnp.float32)
                    * (dt_rank ** -0.5)).astype(dtype),
        "dt_bias": jnp.full((d_inner,), -4.6, dtype),  # softplus^-1(0.01)
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, d_state + 1, dtype=jnp.float32), (d_inner, d_state))),
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": (jax.random.normal(ks[4], (d_inner, cfg.d_model), jnp.float32)
                     * (d_inner ** -0.5)).astype(dtype),
    }
    return p


def _ssm_inputs(params: Params, cfg: ModelConfig, u: jax.Array):
    """Per-timestep SSM coefficients from the post-conv activations.

    u: (b, s, d_inner) -> delta (b,s,d_inner), B (b,s,d_state), C (b,s,d_state).
    """
    _, d_state, _, dt_rank = _dims(cfg)
    proj = u @ params["x_proj"]
    dt, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    delta = jax.nn.softplus(dt @ params["dt_proj"]
                            + params["dt_bias"].astype(dt.dtype))
    return delta.astype(jnp.float32), Bc.astype(jnp.float32), Cc.astype(jnp.float32)


def _conv_causal(params: Params, x: jax.Array, tail: jax.Array | None):
    """Depthwise causal conv along time.  x: (b, s, d_inner)."""
    d_conv = params["conv_w"].shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], d_conv - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    new_tail = xp[:, -(d_conv - 1):] if d_conv > 1 else tail
    out = sum(
        xp[:, i:i + x.shape[1]] * params["conv_w"][i]
        for i in range(d_conv)
    ) + params["conv_b"]
    return out, new_tail


def mamba_apply(params: Params, cfg: ModelConfig, x: jax.Array,
                *, chunked: bool = False, chunk: int = 128,
                return_state: bool = False):
    """Full-sequence selective scan.  x: (b, s, d_model) -> same.

    With ``return_state`` also returns the final {"conv", "ssm"} state for
    prefill -> decode handoff.
    """
    b, s, _ = x.shape
    d_inner, d_state, _, _ = _dims(cfg)
    xz = x @ params["in_proj"]
    u, z = jnp.split(xz, 2, axis=-1)
    u, conv_tail = _conv_causal(params, u, None)
    u = jax.nn.silu(u)
    delta, Bc, Cc = _ssm_inputs(params, cfg, u)
    A = -jnp.exp(params["A_log"])  # (d_inner, d_state)

    uf = u.astype(jnp.float32)
    # Discretize: a_t = exp(delta_t * A), b_t = delta_t * B_t * u_t.
    if chunked:
        y, h_final = _chunked_scan(A, delta, Bc, Cc, uf, chunk)
    else:
        def step(h, inp):
            d_t, b_t, c_t, u_t = inp  # (b,d_inner) (b,d_state) (b,d_state) (b,d_inner)
            a_t = jnp.exp(d_t[..., None] * A[None])  # (b, d_inner, d_state)
            h = a_t * h + (d_t * u_t)[..., None] * b_t[:, None, :]
            y_t = jnp.einsum("bds,bs->bd", h, c_t)
            return h, y_t

        h0 = jnp.zeros((b, d_inner, d_state), jnp.float32)
        xs = (jnp.moveaxis(delta, 1, 0), jnp.moveaxis(Bc, 1, 0),
              jnp.moveaxis(Cc, 1, 0), jnp.moveaxis(uf, 1, 0))
        h_final, ys = jax.lax.scan(step, h0, xs)
        y = jnp.moveaxis(ys, 0, 1)  # (b, s, d_inner)

    y = y + uf * params["D"]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = (y.astype(x.dtype)) @ params["out_proj"]
    if return_state:
        return out, {"conv": conv_tail, "ssm": h_final}
    return out


def _chunked_scan(A, delta, Bc, Cc, uf, chunk: int):
    """Chunk-parallel scan: associative within chunks, sequential across."""
    b, s, d_inner = uf.shape
    d_state = A.shape[-1]
    if s % chunk:
        raise ValueError(f"seq {s} not divisible by chunk {chunk}")
    nck = s // chunk

    a = jnp.exp(delta[..., None] * A[None, None])  # (b, s, d_inner, d_state)
    bx = (delta * uf)[..., None] * Bc[:, :, None, :]
    a = constrain(a, "ssm_inner")
    bx = constrain(bx, "ssm_inner")

    a = a.reshape(b, nck, chunk, d_inner, d_state)
    bx = bx.reshape(b, nck, chunk, d_inner, d_state)
    Ccr = Cc.reshape(b, nck, chunk, d_state)

    def assoc(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    # Within-chunk inclusive scans (parallel over b, nck).
    a_sc, b_sc = jax.lax.associative_scan(assoc, (a, bx), axis=2)

    def carry_step(h, inp):
        a_sc_c, b_sc_c, c_c = inp  # (b, chunk, d_inner, d_state) ...
        h_all = a_sc_c * h[:, None] + b_sc_c
        y_c = jnp.einsum("bcds,bcs->bcd", h_all, c_c)
        return h_all[:, -1], y_c

    h0 = jnp.zeros((b, d_inner, d_state), jnp.float32)
    xs = (jnp.moveaxis(a_sc, 1, 0), jnp.moveaxis(b_sc, 1, 0),
          jnp.moveaxis(Ccr, 1, 0))
    h_final, ys = jax.lax.scan(carry_step, h0, xs)
    y = constrain(jnp.moveaxis(ys, 0, 1).reshape(b, s, d_inner), "ssm_y")
    return y, h_final


def mamba_init_state(cfg: ModelConfig, batch: int) -> dict[str, jax.Array]:
    d_inner, d_state, d_conv, _ = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, d_conv - 1, d_inner), jnp.dtype(cfg.dtype)),
        "ssm": jnp.zeros((batch, d_inner, d_state), jnp.float32),
    }


def mamba_decode_step(params: Params, cfg: ModelConfig, x: jax.Array,
                      state: dict[str, jax.Array]
                      ) -> tuple[jax.Array, dict[str, jax.Array]]:
    """One-token update.  x: (b, d_model)."""
    xz = x[:, None, :] @ params["in_proj"]
    u, z = jnp.split(xz, 2, axis=-1)  # (b, 1, d_inner)
    u, new_tail = _conv_causal(params, u, state["conv"])
    u = jax.nn.silu(u)
    delta, Bc, Cc = _ssm_inputs(params, cfg, u)
    A = -jnp.exp(params["A_log"])
    d_t, b_t, c_t, u_t = delta[:, 0], Bc[:, 0], Cc[:, 0], u[:, 0].astype(jnp.float32)
    a_t = jnp.exp(d_t[..., None] * A[None])
    h = a_t * state["ssm"] + (d_t * u_t)[..., None] * b_t[:, None, :]
    y = jnp.einsum("bds,bs->bd", h, c_t) + u_t * params["D"]
    y = y * jax.nn.silu(z[:, 0].astype(jnp.float32))
    out = y.astype(x.dtype) @ params["out_proj"]
    return out, {"conv": new_tail, "ssm": h}
