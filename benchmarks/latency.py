"""Efficiency benchmarks (paper §5.2) — cost-model-driven on this CPU
container, with real wall-clock microbenchmarks where the algorithm itself
(not the hardware) is under test.

The paper's efficiency premise is that decode attention is HBM-bound; all
speedup numbers here derive from the byte-traffic model at TPU-v5e
bandwidth (``benchmarks.common``), using the *measured* post-pruning
budgets from the accuracy benches where applicable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    HBM_BW,
    attn_bytes_full,
    attn_bytes_quest,
    attn_bytes_quest_twi,
    bytes_to_us,
    csv_row,
    timed,
)


def fig7_attention_speedup():
    """Fig. 7: self-attention latency across (seq, batch) — FA2(full) vs
    FlashInfer(full) vs Quest vs Quest-Twi, from the HBM traffic model.

    B0 = n/4 (paper's conservative selector budget), B1 = 2% of n (the
    measured post-pruning budget scale, Tables 2/5).

    The dense-vs-compact columns price the *whole* Twilight operator
    (select + estimate + top-p + attend) from ``analysis.costs``: dense
    masks make every stage O(n); the compact index pipeline scales with
    B0 (serving config: pruned_cap_frac=1/4 re-compacts the attended
    buffer toward B1)."""
    import dataclasses

    from repro.analysis.costs import (
        serving_pipeline_config,
        twilight_stage_bytes,
    )

    hkv, d = 8, 128
    hq = 4 * hkv  # LLaMA-class GQA group of 4
    tw_compact = serving_pipeline_config()
    tw_dense = dataclasses.replace(tw_compact, compact=False,
                                   pruned_cap_frac=None)
    for n in (8192, 32768, 65536, 131072):
        for batch in (8, 64):
            b0, b1 = n // 4, max(64, int(0.02 * n))
            full = bytes_to_us(attn_bytes_full(n, hkv, d), batch)
            quest = bytes_to_us(attn_bytes_quest(n, hkv, d, b0), batch)
            twi = bytes_to_us(attn_bytes_quest_twi(n, hkv, d, b0, b1), batch)
            dense = bytes_to_us(
                twilight_stage_bytes(tw_dense, n, hq, hkv, d)["total"], batch)
            compact = bytes_to_us(
                twilight_stage_bytes(tw_compact, n, hq, hkv, d)["total"],
                batch)
            csv_row(f"fig7_full_n{n}_b{batch}", full, "speedup=1.00")
            csv_row(f"fig7_quest_n{n}_b{batch}", quest,
                    f"speedup={full / quest:.2f}")
            csv_row(f"fig7_quest_twi_n{n}_b{batch}", twi,
                    f"speedup={full / twi:.2f};vs_quest={quest / twi:.2f}")
            csv_row(f"fig7_twi_dense_n{n}_b{batch}", dense,
                    "compact_vs_dense=1.00")
            csv_row(f"fig7_twi_compact_n{n}_b{batch}", compact,
                    f"compact_vs_dense={dense / compact:.2f}")


def fig8_e2e_tpot():
    """Fig. 8: end-to-end TPOT — weights + attention traffic per token.

    7B-class GQA model (LLaMA-3.1-8B-like: 32L, kv=8, d_h=128)."""
    n_layers, hkv, d = 32, 8, 128
    weight_bytes = 8e9 * 2  # 8B params bf16
    for n in (16384, 32768):
        for batch in (32, 128, 256):
            b0, b1 = n // 4, max(64, int(0.02 * n))
            w_us = weight_bytes / HBM_BW * 1e6  # read once per step
            full = w_us + batch * n_layers * bytes_to_us(
                attn_bytes_full(n, hkv, d))
            quest = w_us + batch * n_layers * bytes_to_us(
                attn_bytes_quest(n, hkv, d, b0))
            twi = w_us + batch * n_layers * bytes_to_us(
                attn_bytes_quest_twi(n, hkv, d, b0, b1))
            csv_row(f"fig8_tpot_full_n{n}_b{batch}", full, "speedup=1.00")
            csv_row(f"fig8_tpot_quest_n{n}_b{batch}", quest,
                    f"speedup={full / quest:.2f}")
            csv_row(f"fig8_tpot_quest_twi_n{n}_b{batch}", twi,
                    f"speedup={full / twi:.2f};vs_quest={quest / twi:.2f}")


def fig10_time_breakdown():
    """Fig. 10: T_TokenSel + T_Pruner + T_SparseAttn, 32k context.

    Matches the paper's theoretical model in §4.3: Quest at B0=8192 (1/4),
    Twilight prunes to B1=256.  Also reports the same breakdown for the
    dense-mask vs compact-index pipeline from ``analysis.costs``, and the
    staged-three-launch vs fused-single-launch pipeline model."""
    import dataclasses

    from repro.analysis.costs import (
        serving_pipeline_config,
        twilight_pipeline_traffic,
        twilight_stage_bytes,
    )

    n, hkv, d, page = 32768, 8, 128, 64
    hq = 4 * hkv
    b0, b1 = 8192, 256
    t_sel = bytes_to_us(2 * (n // page) * hkv * d * 2)  # page metadata scan
    t_prune = bytes_to_us(b0 * hkv * (d // 2 + 8) + 4 * b0 * hkv)
    t_attn_quest = bytes_to_us(2 * b0 * hkv * d * 2)
    t_attn_twi = bytes_to_us(2 * b1 * hkv * d * 2)
    tw_compact = serving_pipeline_config()
    tw_dense = dataclasses.replace(tw_compact, compact=False,
                                   pruned_cap_frac=None)
    st_dense = twilight_stage_bytes(tw_dense, n, hq, hkv, d)
    st_compact = twilight_stage_bytes(tw_compact, n, hq, hkv, d)
    pipe_staged = twilight_pipeline_traffic(tw_compact, n, hq, hkv, d,
                                            fused=False)
    pipe_fused = twilight_pipeline_traffic(tw_compact, n, hq, hkv, d,
                                           fused=True)
    for batch in (16, 64, 128):
        quest_total = batch * (t_sel + t_attn_quest)
        twi_total = batch * (t_sel + t_prune + t_attn_twi)
        csv_row(f"fig10_quest_b{batch}", quest_total,
                f"sel={batch * t_sel:.1f};attn={batch * t_attn_quest:.1f}")
        csv_row(f"fig10_quest_twi_b{batch}", twi_total,
                f"sel={batch * t_sel:.1f};prune={batch * t_prune:.1f};"
                f"attn={batch * t_attn_twi:.1f};"
                f"speedup={quest_total / twi_total:.2f}")
        for tag, st in (("dense", st_dense), ("compact", st_compact)):
            total = bytes_to_us(st["total"], batch)
            csv_row(
                f"fig10_twi_{tag}_b{batch}", total,
                f"sel={bytes_to_us(st['select'], batch):.1f};"
                f"est={bytes_to_us(st['estimate'], batch):.1f};"
                f"topp={bytes_to_us(st['topp'], batch):.1f};"
                f"attn={bytes_to_us(st['attend'], batch):.1f};"
                f"compact_vs_dense="
                f"{st_dense['total'] / st['total']:.2f}")
        # Launch-structure model: the staged three-launch pipeline (inter-
        # stage rows round-trip HBM) vs the single fused launch.
        csv_row(f"fig10_twi_fused_b{batch}",
                bytes_to_us(pipe_fused["total"], batch),
                f"staged_us={bytes_to_us(pipe_staged['total'], batch):.1f};"
                f"fused_vs_staged="
                f"{pipe_staged['total'] / pipe_fused['total']:.2f};"
                f"launches=3_vs_1")
    # The paper's §4.3 closed form for reference.
    theory = (n / 16 + b0) / (n / 16 + b0 / 4 + b1)
    csv_row("fig10_theory_speedup", 0.0, f"speedup={theory:.2f}")


def serve_mixed_workload(batch: int = 8, n_requests: int = 64, seed: int = 0):
    """Continuous (paged) vs wave batching on a mixed request set — modeled.

    7B-class GQA model (32L, kv=8, d_h=128), Quest+Twilight attention
    traffic per live slot, full weight read per engine step.  The wave
    scheduler decodes every slot for the wave's max(max_new_tokens) and
    keeps appending cache rows for finished slots (exactly what
    ``DecodeEngine(paged=False)`` computes); the continuous scheduler
    retires a slot the step it finishes and admits the next request
    immediately (``DecodeEngine(paged=True)``), so only live slots spend
    attention traffic.  Prefill cost is identical in both and omitted.
    """
    rng = np.random.default_rng(seed)
    n_layers, hkv, d = 32, 8, 128
    weight_bytes = 8e9 * 2  # 8B params bf16, read once per step
    w_us = weight_bytes / HBM_BW * 1e6
    prompts = rng.integers(2048, 16384, n_requests)
    max_new = rng.choice([16, 32, 64, 128, 256, 512], n_requests,
                         p=[0.25, 0.2, 0.2, 0.15, 0.12, 0.08])
    total_tokens = int(max_new.sum())

    def attn_us(ctx: int) -> float:
        b0 = max(64, ctx // 4)
        b1 = max(64, int(0.02 * ctx))
        return n_layers * bytes_to_us(attn_bytes_quest_twi(ctx, hkv, d, b0, b1))

    # Wave scheduler: FIFO waves of `batch`, every slot runs to the wave max.
    wave_us = 0.0
    for w0 in range(0, n_requests, batch):
        wave = list(range(w0, min(w0 + batch, n_requests)))
        for t in range(int(max_new[wave].max())):
            wave_us += w_us + sum(attn_us(int(prompts[i]) + t) for i in wave)

    # Continuous scheduler: retire + admit every step.
    cont_us = 0.0
    queue = list(range(n_requests))
    slots: list[list[int] | None] = [None] * batch  # [ctx, remaining]
    while queue or any(s is not None for s in slots):
        for j in range(batch):
            if slots[j] is None and queue:
                i = queue.pop(0)
                slots[j] = [int(prompts[i]), int(max_new[i])]
        cont_us += w_us + sum(attn_us(s[0]) for s in slots if s is not None)
        for j in range(batch):
            if slots[j] is not None:
                slots[j][0] += 1
                slots[j][1] -= 1
                if slots[j][1] == 0:
                    slots[j] = None

    wave_tok_s = total_tokens / (wave_us * 1e-6)
    cont_tok_s = total_tokens / (cont_us * 1e-6)
    csv_row(f"mixed_wave_b{batch}", wave_us, f"tok_s={wave_tok_s:.1f}")
    csv_row(f"mixed_continuous_b{batch}", cont_us,
            f"tok_s={cont_tok_s:.1f};speedup={wave_us / cont_us:.2f}")
    return wave_tok_s, cont_tok_s


def serve_shared_prefix_workload(batch: int = 8, n_requests: int = 64,
                                 prefix_len: int = 8192,
                                 suffix_len: int = 512, max_new: int = 128,
                                 seed: int = 0,
                                 json_path: str | None = None,
                                 fused: bool = True):
    """Prefix sharing (COW pages + chunked prefill) vs full re-prefill —
    modeled.

    Every request shares a ``prefix_len`` system/few-shot prefix and adds a
    private suffix — the fleet-dominant regime.  7B-class GQA model (32L,
    kv=8, d_h=128).  Prefill is chunked (2k tokens): each chunk reads the
    weights once plus the K/V context accumulated so far (the causal
    attention traffic).  With sharing, every request after the first
    prefills only its suffix; without, the full prompt.  Decode cost
    (Quest+Twilight traffic over the full context) is identical in both —
    the win is all TTFT, which compounds into tok/s because the engine's
    prefill chunks and decode steps share one serial device queue.

    Reports per-mode mean TTFT and end-to-end tok/s; optionally dumps the
    rows as JSON (the CI perf artifact).  With ``fused`` (default), the
    share-on run is additionally priced under the launch-structure pipeline
    model from ``analysis.costs`` — staged three-launch vs fused
    single-launch (``kernels/fused_decode``) — as extra ``_fused`` /
    ``_pipeline_staged`` rows, so the CI perf-trajectory gate tracks the
    fused speedup alongside the sharing one (legacy rows are untouched).
    """
    rng = np.random.default_rng(seed)
    n_layers, hkv, d = 32, 8, 128
    weight_bytes = 8e9 * 2  # 8B params bf16, read once per step/chunk
    w_us = weight_bytes / HBM_BW * 1e6
    chunk = 2048
    suffixes = rng.integers(max(1, suffix_len // 4), suffix_len + 1,
                            n_requests)
    new_tokens = rng.integers(max(1, max_new // 4), max_new + 1, n_requests)
    total_new = int(new_tokens.sum())

    def attn_us(ctx: int) -> float:
        b0 = max(64, ctx // 4)
        b1 = max(64, int(0.02 * ctx))
        return n_layers * bytes_to_us(attn_bytes_quest_twi(ctx, hkv, d, b0, b1))

    def prefill_us(start: int, end: int) -> float:
        """Chunked causal prefill of tokens [start, end): per chunk, one
        weight pass + K/V reads over everything resident so far."""
        us, s = 0.0, start
        while s < end:
            e = min(s + chunk, end)
            us += w_us + n_layers * bytes_to_us(2 * e * hkv * d * 2)
            s = e
        return us

    def run(share: bool, attn_fn=attn_us,
            prefill_fn=None) -> tuple[float, float]:
        """Serial engine queue: admissions prefill (suffix or full prompt),
        then every live slot decodes.  Returns (mean TTFT us, total us)."""
        prefill_fn = prefill_fn or prefill_us
        ttft, total_us = [], 0.0
        queue = list(range(n_requests))
        slots: list[list[int] | None] = [None] * batch  # [ctx, remaining]
        cached = False  # the first request prefills the prefix either way
        while queue or any(s is not None for s in slots):
            for j in range(batch):
                if slots[j] is None and queue:
                    i = queue.pop(0)
                    s_total = prefix_len + int(suffixes[i])
                    start = prefix_len if (share and cached) else 0
                    p_us = prefill_fn(start, s_total)
                    cached = True
                    total_us += p_us  # chunks stall the shared queue
                    ttft.append(total_us)
                    slots[j] = [s_total, int(new_tokens[i])]
            total_us += w_us + sum(attn_fn(s[0]) for s in slots
                                   if s is not None)
            for j in range(batch):
                if slots[j] is not None:
                    slots[j][0] += 1
                    slots[j][1] -= 1
                    if slots[j][1] == 0:
                        slots[j] = None
        return float(np.mean(ttft)), total_us

    rows = []
    for tag, share in (("off", False), ("on", True)):
        ttft_us, total = run(share)
        tok_s = total_new / (total * 1e-6)
        rows.append({"name": f"shared_prefix_share_{tag}_b{batch}",
                     "ttft_us": ttft_us, "total_us": total, "tok_s": tok_s})
        csv_row(f"shared_prefix_share_{tag}_b{batch}", total,
                f"ttft_us={ttft_us:.1f};tok_s={tok_s:.1f}")
    speed = rows[0]["total_us"] / rows[1]["total_us"]
    ttft_speed = rows[0]["ttft_us"] / rows[1]["ttft_us"]
    csv_row(f"shared_prefix_speedup_b{batch}", 0.0,
            f"ttft={ttft_speed:.2f};tok_s={speed:.2f}")
    rows.append({"name": f"shared_prefix_speedup_b{batch}",
                 "ttft_speedup": ttft_speed, "tok_s_speedup": speed})
    if fused:
        rows.extend(_fused_axis_rows(lambda fn: run(True, fn),
                                     "shared_prefix", batch, total_new,
                                     n_layers, hkv, d))
        rows.extend(_sparse_prefill_axis_rows(
            lambda fn: run(False, prefill_fn=fn), "shared_prefix", batch,
            total_new, n_layers, hkv, d, chunk, w_us))
    if json_path:
        import json
        with open(json_path, "w") as f:
            json.dump({"workload": "shared-prefix", "batch": batch,
                       "n_requests": n_requests, "prefix_len": prefix_len,
                       "rows": rows}, f, indent=2)
    return rows


def _fused_axis_rows(runner, prefix: str, batch: int, total_new: int,
                     n_layers: int, hkv: int, d: int) -> list[dict]:
    """Re-price one scheduler run under the launch-structure pipeline model.

    ``runner(attn_fn) -> (mean TTFT us, total us)`` replays the workload's
    scheduler with a per-step attention cost function.  Two variants are
    priced from ``analysis.costs.twilight_pipeline_traffic``: the staged
    three-launch compact pipeline (inter-stage rows round-trip HBM, final
    gather over the capped buffer) and the fused single-launch kernel
    (``kernels/fused_decode`` — survivor-only K/V reads).  Emits
    ``{prefix}_pipeline_staged`` / ``{prefix}_fused`` rows plus the
    speedup row the CI perf-trajectory gate tracks.
    """
    from repro.analysis.costs import (
        serving_pipeline_config,
        twilight_pipeline_traffic,
    )

    tw = serving_pipeline_config()
    hq = 4 * hkv
    out, totals = [], {}
    for tag, fl in (("pipeline_staged", False), ("fused", True)):
        def attn_fn(ctx: int, fl=fl) -> float:
            tr = twilight_pipeline_traffic(tw, ctx, hq, hkv, d, fused=fl)
            return n_layers * bytes_to_us(tr["total"])

        ttft_us, total = runner(attn_fn)
        totals[tag] = (ttft_us, total)
        tok_s = total_new / (total * 1e-6)
        out.append({"name": f"{prefix}_{tag}_b{batch}", "ttft_us": ttft_us,
                    "total_us": total, "tok_s": tok_s})
        csv_row(f"{prefix}_{tag}_b{batch}", total,
                f"ttft_us={ttft_us:.1f};tok_s={tok_s:.1f}")
    speed = totals["pipeline_staged"][1] / totals["fused"][1]
    ttft_speed = totals["pipeline_staged"][0] / totals["fused"][0]
    csv_row(f"{prefix}_fused_speedup_b{batch}", 0.0,
            f"ttft={ttft_speed:.2f};tok_s={speed:.2f}")
    out.append({"name": f"{prefix}_fused_speedup_b{batch}",
                "ttft_speedup": ttft_speed, "tok_s_speedup": speed})

    # Survivor-DMA granularity axis: the fused run re-priced at per-row vs
    # run-coalesced transaction granularity — ``total_eff`` = payload +
    # per-copy descriptor overhead, the bytes a bandwidth model should
    # price.  Run telemetry (transactions and effective bytes at the 32k
    # reference context) rides along in the JSON rows.
    ref_n = 32768
    for tag, dma in (("fused_dma_row", "row"), ("fused_dma_run", "run")):
        def attn_fn(ctx: int, dma=dma) -> float:
            tr = twilight_pipeline_traffic(tw, ctx, hq, hkv, d, fused=True,
                                           dma=dma)
            return n_layers * bytes_to_us(tr["total_eff"])

        ttft_us, total = runner(attn_fn)
        totals[tag] = (ttft_us, total)
        tok_s = total_new / (total * 1e-6)
        ref = twilight_pipeline_traffic(tw, ref_n, hq, hkv, d, fused=True,
                                        dma=dma)
        out.append({"name": f"{prefix}_{tag}_b{batch}", "ttft_us": ttft_us,
                    "total_us": total, "tok_s": tok_s,
                    "attend_txns_32k": ref["attend_txns"],
                    "eff_bytes_32k": ref["total_eff"]})
        csv_row(f"{prefix}_{tag}_b{batch}", total,
                f"ttft_us={ttft_us:.1f};tok_s={tok_s:.1f};"
                f"txns_32k={ref['attend_txns']:.0f}")
    dma_speed = totals["fused_dma_row"][1] / totals["fused_dma_run"][1]
    csv_row(f"{prefix}_fused_dma_speedup_b{batch}", 0.0,
            f"tok_s={dma_speed:.2f}")
    out.append({"name": f"{prefix}_fused_dma_speedup_b{batch}",
                "tok_s_speedup": dma_speed})

    # Multi-token window axis: one fused launch decodes k queued tokens
    # (preemption replay / speculative verify) against the union of their
    # survivor sets — priced per token, run-coalesced DMA.
    for k in (1, 4):
        def attn_fn(ctx: int, k=k) -> float:
            tr = twilight_pipeline_traffic(tw, ctx, hq, hkv, d, fused=True,
                                           dma="run", k=k)
            return n_layers * bytes_to_us(tr["per_token"])

        ttft_us, total = runner(attn_fn)
        totals[f"multitok_k{k}"] = (ttft_us, total)
        tok_s = total_new / (total * 1e-6)
        ref = twilight_pipeline_traffic(tw, ref_n, hq, hkv, d, fused=True,
                                        dma="run", k=k)
        out.append({"name": f"{prefix}_fused_multitok_k{k}_b{batch}",
                    "ttft_us": ttft_us, "total_us": total, "tok_s": tok_s,
                    "launches_per_token": ref["launches_per_token"],
                    "per_token_bytes_32k": ref["per_token"]})
        csv_row(f"{prefix}_fused_multitok_k{k}_b{batch}", total,
                f"ttft_us={ttft_us:.1f};tok_s={tok_s:.1f};"
                f"launches_per_tok={ref['launches_per_token']:.2f}")
    mt_speed = totals["multitok_k1"][1] / totals["multitok_k4"][1]
    csv_row(f"{prefix}_fused_multitok_speedup_b{batch}", 0.0,
            f"tok_s={mt_speed:.2f};launch_x=4.00")
    out.append({"name": f"{prefix}_fused_multitok_speedup_b{batch}",
                "tok_s_speedup": mt_speed, "launch_x": 4.0})

    # Hierarchical page-nucleus axis: the fused run re-priced with the
    # page-level top-p on (page_top_p=0.9) — nucleus-dead pages' INT4
    # codes are never scored, so the estimate stage shrinks to the
    # surviving pages.  The ``_hier_*`` rows feed the CI perf gate.
    import dataclasses
    twh = dataclasses.replace(tw, page_top_p=0.9)

    def attn_fn(ctx: int) -> float:
        tr = twilight_pipeline_traffic(twh, ctx, hq, hkv, d, fused=True,
                                       dma="run")
        return n_layers * bytes_to_us(tr["total_eff"])

    ttft_us, total = runner(attn_fn)
    tok_s = total_new / (total * 1e-6)
    ref_h = twilight_pipeline_traffic(twh, ref_n, hq, hkv, d, fused=True,
                                      dma="run")
    ref_f = twilight_pipeline_traffic(tw, ref_n, hq, hkv, d, fused=True,
                                      dma="run")
    est_x = ref_f["estimate"] / ref_h["estimate"]
    out.append({"name": f"{prefix}_hier_fused_b{batch}",
                "ttft_us": ttft_us, "total_us": total, "tok_s": tok_s,
                "hier_estimate_bytes_32k": ref_h["estimate"],
                "flat_estimate_bytes_32k": ref_f["estimate"]})
    csv_row(f"{prefix}_hier_fused_b{batch}", total,
            f"ttft_us={ttft_us:.1f};tok_s={tok_s:.1f};"
            f"est_bytes_32k={ref_h['estimate']:.0f}")
    hier_speed = totals["fused_dma_run"][1] / total
    out.append({"name": f"{prefix}_hier_speedup_b{batch}",
                "tok_s_speedup": hier_speed, "estimate_x": est_x})
    csv_row(f"{prefix}_hier_speedup_b{batch}", 0.0,
            f"tok_s={hier_speed:.2f};est_x={est_x:.2f}")
    return out


def _sparse_prefill_axis_rows(runner, prefix: str, batch: int,
                              total_new: int, n_layers: int, hkv: int,
                              d: int, chunk: int,
                              w_us: float) -> list[dict]:
    """Re-price one scheduler run's prefill under the TTFT-path model.

    ``runner(prefill_fn) -> (mean TTFT us, total us)`` replays the
    workload's scheduler with a per-admission prefill cost function —
    sharing *off*, the full-prompt-prefill regime where the TTFT is
    attention-dominated (with sharing on, admissions prefill only their
    suffix and the kernel has little left to prune).  Two variants are
    priced from
    ``analysis.costs.prefill_attention_traffic``: the dense flash oracle
    (every query tile streams its whole causal context) and the
    page-nucleus sparse prefill kernel (``kernels/sparse_prefill``,
    ``prefill_top_p=0.9`` — survivor pages only).  Emits
    ``{prefix}_dense_prefill`` / ``{prefix}_sparse_prefill`` rows plus
    the ``{prefix}_prefill_speedup`` row the CI perf-trajectory gate
    tracks; ``prefill_bytes_x_64k`` is the modeled per-layer prefill
    byte reduction at the 64k reference context.
    """
    import dataclasses

    from repro.analysis.costs import (
        prefill_attention_traffic,
        serving_pipeline_config,
    )

    tw = serving_pipeline_config()
    hq = 4 * hkv
    ref_n = 65536
    out, totals = [], {}
    for tag, p in (("dense_prefill", None), ("sparse_prefill", 0.9)):
        twp = dataclasses.replace(tw, prefill_top_p=p)

        def prefill_fn(start: int, end: int, twp=twp) -> float:
            us, s = 0.0, start
            while s < end:
                e = min(s + chunk, end)
                tr = prefill_attention_traffic(twp, e - s, hq, hkv, d, n=e)
                us += w_us + n_layers * bytes_to_us(tr["total"])
                s = e
            return us

        ttft_us, total = runner(prefill_fn)
        totals[tag] = (ttft_us, total)
        tok_s = total_new / (total * 1e-6)
        out.append({"name": f"{prefix}_{tag}_b{batch}", "ttft_us": ttft_us,
                    "total_us": total, "tok_s": tok_s})
        csv_row(f"{prefix}_{tag}_b{batch}", total,
                f"ttft_us={ttft_us:.1f};tok_s={tok_s:.1f}")
    speed = totals["dense_prefill"][1] / totals["sparse_prefill"][1]
    ttft_speed = totals["dense_prefill"][0] / totals["sparse_prefill"][0]
    ref = prefill_attention_traffic(
        dataclasses.replace(tw, prefill_top_p=0.9), ref_n, hq, hkv, d)
    out.append({"name": f"{prefix}_prefill_speedup_b{batch}",
                "ttft_speedup": ttft_speed, "tok_s_speedup": speed,
                "prefill_bytes_x_64k": ref["bytes_x"]})
    csv_row(f"{prefix}_prefill_speedup_b{batch}", 0.0,
            f"ttft={ttft_speed:.2f};tok_s={speed:.2f};"
            f"bytes_x_64k={ref['bytes_x']:.2f}")
    return out


def serve_persistent_workload(batch: int = 8, n_batches: int = 4,
                              requests_per_batch: int = 8,
                              prefix_len: int = 8192, suffix_len: int = 512,
                              max_new: int = 128, seed: int = 0,
                              json_path: str | None = None,
                              fused: bool = True):
    """Persistent session vs fresh-engine-per-call — modeled.

    ``n_batches`` successive ``submit()`` batches (each: shared system
    prefix + private suffixes) are served either by ONE persistent engine —
    whose radix tree survives between calls, so every batch after the first
    prefills only suffixes — or by a fresh engine per batch, which re-pays
    the prefix prefill once per call (the pre-persistence engine: the pool
    and tree were torn down after every ``generate()``).  Same 7B-class
    cost model as the shared-prefix workload.

    Reports per-mode radix-tree hit rate, mean TTFT, and end-to-end tok/s;
    optionally dumps the rows as JSON (the CI perf artifact).  With
    ``fused`` (default), the persistent-mode run is additionally priced
    under the staged-vs-fused launch-structure pipeline model (extra
    ``_fused`` / ``_pipeline_staged`` rows; legacy rows untouched).
    """
    if n_batches < 1 or requests_per_batch < 1:
        raise ValueError(f"need >= 1 batch of >= 1 request, got "
                         f"{n_batches} x {requests_per_batch}")
    rng = np.random.default_rng(seed)
    n_layers, hkv, d = 32, 8, 128
    weight_bytes = 8e9 * 2  # 8B params bf16, read once per step/chunk
    w_us = weight_bytes / HBM_BW * 1e6
    chunk = 2048
    n_total = n_batches * requests_per_batch
    suffixes = rng.integers(max(1, suffix_len // 4), suffix_len + 1, n_total)
    new_tokens = rng.integers(max(1, max_new // 4), max_new + 1, n_total)
    total_new = int(new_tokens.sum())

    def attn_us(ctx: int) -> float:
        b0 = max(64, ctx // 4)
        b1 = max(64, int(0.02 * ctx))
        return n_layers * bytes_to_us(attn_bytes_quest_twi(ctx, hkv, d, b0, b1))

    def prefill_us(start: int, end: int) -> float:
        us, s = 0.0, start
        while s < end:
            e = min(s + chunk, end)
            us += w_us + n_layers * bytes_to_us(2 * e * hkv * d * 2)
            s = e
        return us

    def run(persistent: bool, attn_fn=attn_us,
            prefill_fn=None) -> tuple[float, float, float]:
        """Serve the batches serially.  Returns (hit rate, mean TTFT us,
        total us)."""
        prefill_fn = prefill_fn or prefill_us
        ttft, total_us, hits = [], 0.0, 0
        cached = False  # radix tree holds the prefix
        for b0_idx in range(n_batches):
            if not persistent:
                cached = False  # fresh engine: tree torn down with the call
            queue = list(range(b0_idx * requests_per_batch,
                               (b0_idx + 1) * requests_per_batch))
            slots: list[list[int] | None] = [None] * batch
            while queue or any(s is not None for s in slots):
                for j in range(batch):
                    if slots[j] is None and queue:
                        i = queue.pop(0)
                        s_total = prefix_len + int(suffixes[i])
                        if cached:
                            hits += 1
                            start = prefix_len
                        else:
                            start = 0
                        p_us = prefill_fn(start, s_total)
                        cached = True
                        total_us += p_us  # chunks stall the shared queue
                        # Queue-inclusive TTFT, same semantics as the
                        # shared-prefix workload (the gate compares both).
                        ttft.append(total_us)
                        slots[j] = [s_total, int(new_tokens[i])]
                total_us += w_us + sum(attn_fn(s[0]) for s in slots
                                       if s is not None)
                for j in range(batch):
                    if slots[j] is not None:
                        slots[j][0] += 1
                        slots[j][1] -= 1
                        if slots[j][1] == 0:
                            slots[j] = None
        return hits / n_total, float(np.mean(ttft)), total_us

    rows = []
    for tag, persistent in (("fresh", False), ("persistent", True)):
        hit_rate, ttft_us, total = run(persistent)
        tok_s = total_new / (total * 1e-6)
        rows.append({"name": f"persistent_{tag}_b{batch}",
                     "hit_rate": hit_rate, "ttft_us": ttft_us,
                     "total_us": total, "tok_s": tok_s})
        csv_row(f"persistent_{tag}_b{batch}", total,
                f"hit_rate={hit_rate:.2f};ttft_us={ttft_us:.1f};"
                f"tok_s={tok_s:.1f}")
    speed = rows[0]["total_us"] / rows[1]["total_us"]
    ttft_speed = rows[0]["ttft_us"] / rows[1]["ttft_us"]
    csv_row(f"persistent_speedup_b{batch}", 0.0,
            f"ttft={ttft_speed:.2f};tok_s={speed:.2f}")
    rows.append({"name": f"persistent_speedup_b{batch}",
                 "ttft_speedup": ttft_speed, "tok_s_speedup": speed})
    if fused:
        rows.extend(_fused_axis_rows(lambda fn: run(True, fn)[1:],
                                     "persistent", batch, total_new,
                                     n_layers, hkv, d))
        rows.extend(_sparse_prefill_axis_rows(
            lambda fn: run(False, prefill_fn=fn)[1:], "persistent", batch,
            total_new, n_layers, hkv, d, chunk, w_us))
    if json_path:
        import json
        with open(json_path, "w") as f:
            json.dump({"workload": "persistent", "batch": batch,
                       "n_batches": n_batches, "prefix_len": prefix_len,
                       "rows": rows}, f, indent=2)
    return rows


# ---------------------------------------------------------------------------
# Perf-trajectory gate: compare a run's JSON rows against a blessed baseline
# ---------------------------------------------------------------------------

# Metrics the gate watches, with their good direction.
_GATE_METRICS = {"tok_s": "higher", "ttft_us": "lower"}


def compare_benchmarks(current: dict, baseline: dict,
                       threshold: float = 0.10) -> tuple[list[dict], str]:
    """Compare two benchmark JSON documents row-by-row.

    Returns ``(regressions, markdown)``: rows whose modeled ``tok_s``
    dropped or ``ttft_us`` rose by more than ``threshold`` relative to the
    baseline, plus a markdown delta table for the CI job summary.  Rows or
    metrics missing on either side are skipped (renames don't fail the
    gate — a removed row simply leaves the trajectory).
    """
    base_rows = {r["name"]: r for r in baseline.get("rows", [])}
    regressions, lines = [], []
    lines.append("| row | metric | baseline | current | delta |")
    lines.append("|---|---|---:|---:|---:|")
    for row in current.get("rows", []):
        base = base_rows.get(row["name"])
        if base is None:
            continue
        for metric, direction in _GATE_METRICS.items():
            if metric not in row or metric not in base:
                continue
            cur, old = float(row[metric]), float(base[metric])
            if old == 0:
                continue
            rel = (cur - old) / old
            worse = rel < -threshold if direction == "higher" \
                else rel > threshold
            flag = " ⛔" if worse else ""
            lines.append(f"| {row['name']} | {metric} | {old:.1f} | "
                         f"{cur:.1f} | {rel:+.1%}{flag} |")
            if worse:
                regressions.append({"name": row["name"], "metric": metric,
                                    "baseline": old, "current": cur,
                                    "rel": rel})
    return regressions, "\n".join(lines)


def run_compare(rows: list[dict], workload: str, baseline_path: str,
                threshold: float, warn_only: bool) -> int:
    """Gate the just-computed ``rows`` against ``baseline_path``.

    Prints the delta table, appends it to ``$GITHUB_STEP_SUMMARY`` when CI
    provides one, and returns the process exit code (nonzero on a >
    ``threshold`` modeled tok/s or TTFT regression unless ``warn_only``).
    """
    import json
    import os
    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"# perf gate: baseline unreadable ({e}) — skipping")
        return 0
    regressions, table = compare_benchmarks(
        {"rows": rows}, baseline, threshold=threshold)
    verdict = ("REGRESSION" if regressions and not warn_only
               else "regression (warn-only)" if regressions else "ok")
    md = (f"### Perf trajectory: `{workload}` — {verdict}\n\n"
          f"threshold ±{threshold:.0%} on modeled tok/s and TTFT\n\n"
          f"{table}\n")
    print(md)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(md + "\n")
    if regressions and not warn_only:
        for r in regressions:
            print(f"# perf gate FAIL: {r['name']} {r['metric']} "
                  f"{r['baseline']:.1f} -> {r['current']:.1f} "
                  f"({r['rel']:+.1%})")
        return 1
    return 0


def tabE_offload():
    """Appendix E: offloading — per-token load cost dominates (PCIe-class
    32 GB/s instead of HBM), so pruned budgets win ~proportionally."""
    pcie = 32e9
    hkv, d = 8, 128
    for n in (10240, 20480, 30720):
        b0, b1 = n // 4, 256
        quest = 2 * b0 * hkv * d * 2 / pcie * 1e6
        twi = (b0 * hkv * (d // 2 + 8) / HBM_BW  # estimate stays on-device
               + 2 * b1 * hkv * d * 2 / pcie) * 1e6
        csv_row(f"tabE_quest_n{n}", quest, "speedup=1.00")
        csv_row(f"tabE_quest_twi_n{n}", twi, f"speedup={quest / twi:.2f}")


def alg1_topp_microbench():
    """Algorithm 1 wall-clock: binary-search top-p vs sort-based oracle
    (both jitted, CPU) — the parallel-friendly claim, measured for real."""
    from repro.core.topp import oracle_topp_mask, topp_mask
    rng = np.random.default_rng(0)
    for n in (4096, 32768):
        w = jax.nn.softmax(
            jnp.asarray(rng.normal(size=(64, n)) * 3, jnp.float32), axis=-1)
        bs = jax.jit(lambda w: topp_mask(w, 0.9).budget)
        so = jax.jit(lambda w: oracle_topp_mask(w, 0.9).budget)
        us_bs, _ = timed(bs, w)
        us_so, _ = timed(so, w)
        csv_row(f"alg1_binary_search_n{n}", us_bs,
                f"vs_sort={us_so / us_bs:.2f}x")
        csv_row(f"alg1_sort_oracle_n{n}", us_so, "baseline")


def kernels_interpret_sanity():
    """Per-kernel interpret-mode sanity timings (correctness-path cost; not
    TPU latency) + the analytic VMEM working set of the chosen BlockSpecs."""
    from repro.kernels.sparse_attn.kernel import sparse_decode_attention
    from repro.kernels.spgemv.kernel import spgemv_scores
    from repro.kernels.quant.kernel import quantize_int4_rows
    rng = np.random.default_rng(1)
    B, g, n, d = 4, 8, 2048, 128
    q = jnp.asarray(rng.normal(size=(B, g, d)), jnp.float32)
    K = jnp.asarray(rng.normal(size=(B, n, d)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(B, n, d)), jnp.float32)
    mask = jnp.asarray(rng.random((B, n)) < 0.02)
    us, _ = timed(lambda: sparse_decode_attention(
        q, K, V, mask, sm_scale=0.088, interpret=True), iters=3, warmup=1)
    vmem_kib = (128 * d * 4 * 2 + g * d * 4 * 2 + 128) / 1024
    csv_row("kernel_sparse_attn_interpret", us, f"vmem_kib={vmem_kib:.0f}")

    pk, sk, zk = quantize_int4_rows(K.reshape(B * n, d), interpret=True)
    packed = pk.reshape(B, n, d // 2)
    us, _ = timed(lambda: spgemv_scores(
        q[..., 0::2], q[..., 1::2], packed, sk.reshape(B, n),
        zk.reshape(B, n), sm_scale=0.088, interpret=True), iters=3, warmup=1)
    csv_row("kernel_spgemv_interpret", us,
            f"bytes_per_token={d // 2 + 8}")
    us, _ = timed(lambda: quantize_int4_rows(K.reshape(B * n, d),
                                             interpret=True),
                  iters=3, warmup=1)
    csv_row("kernel_quant_interpret", us, "ratio=0.28125")  # (d/2+8)/(2d)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workload", default=None,
                    choices=["mixed", "shared-prefix", "persistent"],
                    help="mixed: continuous vs wave batching on mixed "
                         "max_new_tokens; shared-prefix: COW prefix "
                         "sharing + chunked prefill vs full re-prefill; "
                         "persistent: one long-lived engine across N "
                         "submit() batches vs a fresh engine per batch "
                         "(modeled costs)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batches", type=int, default=4,
                    help="successive submit() batches (persistent workload)")
    ap.add_argument("--prefix-len", type=int, default=8192)
    ap.add_argument("--fused", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="also price the serving workloads under the "
                         "staged-vs-fused launch-structure pipeline model "
                         "(extra _pipeline_staged/_fused rows tracked by "
                         "the CI perf gate); --no-fused restores the "
                         "legacy row set")
    ap.add_argument("--json", default=None,
                    help="also dump the workload rows as JSON (CI artifact)")
    ap.add_argument("--compare", default=None, metavar="BASELINE.json",
                    help="perf-trajectory gate: compare this run's rows "
                         "against a baseline JSON; exits nonzero on a "
                         "> threshold modeled tok/s or TTFT regression")
    ap.add_argument("--compare-warn-only", action="store_true",
                    help="report regressions but exit zero (PR builds)")
    ap.add_argument("--compare-threshold", type=float, default=0.10,
                    help="relative regression tolerance (default 10%%)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    rows = None
    if args.workload == "mixed":
        serve_mixed_workload(batch=args.batch, n_requests=args.requests,
                             seed=args.seed)
    elif args.workload == "shared-prefix":
        rows = serve_shared_prefix_workload(batch=args.batch,
                                            n_requests=args.requests,
                                            prefix_len=args.prefix_len,
                                            seed=args.seed,
                                            json_path=args.json,
                                            fused=args.fused)
    elif args.workload == "persistent":
        rows = serve_persistent_workload(
            batch=args.batch, n_batches=max(1, args.batches),
            requests_per_batch=max(1, args.requests
                                   // max(1, args.batches)),
            prefix_len=args.prefix_len, seed=args.seed,
            json_path=args.json, fused=args.fused)
    else:
        for fn in (fig7_attention_speedup, fig8_e2e_tpot,
                   fig10_time_breakdown, tabE_offload, alg1_topp_microbench):
            fn()
    if args.compare:
        if rows is None:
            raise SystemExit("--compare requires --workload "
                             "shared-prefix|persistent")
        raise SystemExit(run_compare(rows, args.workload, args.compare,
                                     args.compare_threshold,
                                     args.compare_warn_only))
