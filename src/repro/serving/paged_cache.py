"""Paged KV-cache pool: vLLM-style block allocator + pool array helpers.

The serving engine provisions ONE shared pool of ``num_pages`` fixed-size
pages per attention layer instead of a contiguous ``(batch, capacity)``
cache per slot.  Each request owns only the pages its tokens actually fill
(prefill allocates ceil(len/page_size); decode allocates one page at each
page boundary), so memory scales with live tokens, not with
``batch * worst_case`` — the substrate that makes continuous batching pay.

Layout (per attention layer, see ``models.model._attn_pool_init``):

* ``k``/``v``:            (num_pages * page_size, hkv, d) token rows
* ``qk_packed/scale/zero``: INT4 shadow cache, same token-row layout
* ``pmax``/``pmin``:      (num_pages, hkv, d) Quest metadata per *physical*
  page — selectors gather it through the per-slot page table
* page table:             (batch, max_pages) i32, engine-managed **host**
  state mirrored to device as plain data each step

Physical page 0 is the **null page**: never allocated, the scatter target
for dead slots and the safe-gather target for invalid index-buffer slots.
All allocation bookkeeping is host-side Python (a free list); device state
never stores pointers, only the page-table array — so the jitted decode
step stays a pure function of arrays and the allocator needs no tracing.
"""

from __future__ import annotations

__all__ = ["NULL_PAGE", "PageAllocator", "pages_for", "pad_to_pages"]

NULL_PAGE = 0


def pages_for(n_tokens: int, page_size: int) -> int:
    """Number of pages needed to hold ``n_tokens`` token rows."""
    return -(-max(0, n_tokens) // page_size)


def pad_to_pages(n_tokens: int, page_size: int) -> int:
    """``n_tokens`` rounded up to a whole number of pages."""
    return pages_for(n_tokens, page_size) * page_size


class PageAllocator:
    """Free-list allocator over physical page ids ``1..num_pages-1``.

    Page 0 (:data:`NULL_PAGE`) is reserved.  Pages are recycled LIFO so a
    steady-state workload keeps touching the same hot pages.  Invariants
    (asserted, and exercised by ``tests/test_paged_cache.py``):

    * a page is never handed out twice without an intervening ``free``
    * ``free`` of an unallocated (or null) page raises
    * ``available + len(allocated) == num_pages - 1`` at all times
    """

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError("need at least one allocatable page + the null page")
        self.num_pages = num_pages
        self._free: list[int] = list(range(num_pages - 1, NULL_PAGE, -1))
        self._allocated: set[int] = set()

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def capacity(self) -> int:
        """Total allocatable pages (excludes the null page)."""
        return self.num_pages - 1

    @property
    def allocated(self) -> frozenset[int]:
        return frozenset(self._allocated)

    def alloc(self, n: int) -> list[int]:
        """Pop ``n`` pages off the free list; raises MemoryError if short."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} pages")
        if n > len(self._free):
            raise MemoryError(
                f"page pool exhausted: want {n}, have {len(self._free)}")
        pages = [self._free.pop() for _ in range(n)]
        self._allocated.update(pages)
        return pages

    def free(self, pages: list[int]) -> None:
        for p in pages:
            if p == NULL_PAGE:
                raise ValueError("cannot free the null page")
            if p not in self._allocated:
                raise ValueError(f"double free of page {p}")
            self._allocated.remove(p)
            self._free.append(p)
