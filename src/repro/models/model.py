"""Unified model: init / train forward / prefill / Twilight decode.

All ten architectures are instances of one block calculus:

    layer = mixer (attn | mamba | mlstm | slstm) [+ cross-attn] [+ ffn|moe]

Layers repeat with period P (Jamba: 8 = 7 mamba + 1 attn, MoE every 2nd;
xLSTM: 7 mLSTM + 1 sLSTM; everything else: P=1).  Parameters are stacked
per position-in-period and the depth dimension is a single ``lax.scan`` —
HLO size and compile time are O(P), not O(L), which is what makes 80
(arch × shape × mesh) dry-run compiles tractable.

Decode integrates the paper's pipeline as a first-class feature: the KV
cache carries an INT4 shadow cache + Quest page metadata, and attention
layers run Select-then-Prune (``repro.core.twilight``) every step.  With
the default ``TwilightConfig.compact=True`` the whole jitted decode step
operates on candidate *index buffers*: the score estimate, top-p search
and final attention are O(B0), and no n-length f32 weights buffer is ever
materialized (``PrunerStats.weights`` is None on this path).  With
``TwilightConfig.fused_backend`` resolving to fused (the TPU default),
the estimate/top-p/attend tail further collapses into ONE Pallas launch
per attention layer per decode step (``kernels/fused_decode``) — both
:func:`decode_step` and :func:`decode_step_paged` pick this up through
``twilight_decode_attention`` with no change to their contracts (paged
mode still translates logical indices through the page table before any
gather, and ``TwilightOutput.slot_weights`` still feeds the H2O page-mass
scatter-add below).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import quant as quant_lib
from repro.core import runs as runs_lib
from repro.core.attention import full_decode_attention, mha_attention
from repro.core.selectors import PageMeta, SelectionContext
from repro.core.twilight import (twilight_decode_attention,
                                 twilight_decode_window_attention)
from repro.kernels.sparse_prefill.ops import sparse_prefill_attend
from repro.models import layers as ly
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.models.common import ModelConfig, block_pattern
from repro.sharding.act import constrain

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Layer-stack schedule
# ---------------------------------------------------------------------------

class LayerSpec(NamedTuple):
    kind: str  # attn | mamba | mlstm | slstm
    is_moe: bool
    has_cross: bool


def layer_schedule(cfg: ModelConfig) -> tuple[list[LayerSpec], int]:
    """Per-position specs for one period, plus the repeat count."""
    pattern = block_pattern(cfg)
    moe_period = cfg.moe.period if cfg.moe else 0

    def spec(i: int) -> LayerSpec:
        is_moe = bool(cfg.moe) and (i % cfg.moe.period == cfg.moe.period - 1)
        return LayerSpec(kind=pattern[i], is_moe=is_moe,
                         has_cross=cfg.encoder_layers > 0)

    # Find the smallest period P consistent with both interleaves.
    candidates = [p for p in range(1, cfg.n_layers + 1) if cfg.n_layers % p == 0]
    for p in candidates:
        if all(spec(i) == spec(i % p) for i in range(cfg.n_layers)):
            return [spec(i) for i in range(p)], cfg.n_layers // p
    raise ValueError(f"no repeating period found for {cfg.name}")


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _mixer_init(cfg: ModelConfig, kind: str, key) -> Params:
    if kind == "attn":
        return ly.attn_init(cfg, key)
    if kind == "mamba":
        return ssm_lib.mamba_init(cfg, key)
    if kind == "mlstm":
        return xlstm_lib.mlstm_init(cfg, key)
    if kind == "slstm":
        return xlstm_lib.slstm_init(cfg, key)
    raise ValueError(kind)


def _block_init(cfg: ModelConfig, spec: LayerSpec, key) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p: Params = {
        "norm1": jnp.ones((cfg.d_model,), dtype),
        "mixer": _mixer_init(cfg, spec.kind, ks[0]),
    }
    if spec.has_cross and spec.kind == "attn":
        p["cross"] = ly.attn_init(cfg, ks[1])
        p["norm_cross"] = jnp.ones((cfg.d_model,), dtype)
    if spec.kind in ("attn", "mamba"):  # xLSTM blocks have no separate FFN
        if spec.is_moe:
            p["norm2"] = jnp.ones((cfg.d_model,), dtype)
            p["ffn"] = ly.moe_init(cfg, ks[2])
        else:
            d_ff = (cfg.moe.dense_d_ff if cfg.moe else 0) or cfg.d_ff
            if d_ff:
                p["norm2"] = jnp.ones((cfg.d_model,), dtype)
                p["ffn"] = ly.mlp_init(cfg, ks[2], d_ff=d_ff)
    return p


def init_params(cfg: ModelConfig, key) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    specs, repeats = layer_schedule(cfg)
    keys = jax.random.split(key, 8)

    params: Params = {
        "embed": (jax.random.normal(keys[0], (cfg.padded_vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = ly.dense_init(keys[1], cfg.d_model,
                                          cfg.padded_vocab, dtype)

    blocks = []
    for p_idx, spec in enumerate(specs):
        layer_keys = jax.random.split(
            jax.random.fold_in(keys[2], p_idx), repeats)
        stacked = jax.vmap(lambda k, s=spec: _block_init(cfg, s, k))(layer_keys)
        blocks.append(stacked)
    params["blocks"] = blocks

    if cfg.encoder_layers:
        enc_cfg = cfg.replace(n_layers=cfg.encoder_layers, moe=None,
                              attn_period=0, encoder_layers=0)
        enc_keys = jax.random.split(keys[3], cfg.encoder_layers)
        enc_spec = LayerSpec(kind="attn", is_moe=False, has_cross=False)
        params["encoder"] = {
            "blocks": jax.vmap(
                lambda k: _block_init(enc_cfg, enc_spec, k))(enc_keys),
            "final_norm": jnp.ones((cfg.d_model,), dtype),
        }
    return params


def count_params(params: Params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# Train / prefill forward
# ---------------------------------------------------------------------------

def _block_apply_train(bp: Params, cfg: ModelConfig, spec: LayerSpec,
                       x: jax.Array, positions: jax.Array,
                       memory: jax.Array | None) -> tuple[jax.Array, jax.Array]:
    """Full-sequence block.  Returns (x, moe aux loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = ly.rms_norm(x, bp["norm1"], cfg.norm_eps)
    if spec.kind == "attn":
        mix = ly.attn_apply(bp["mixer"], cfg, h, positions, causal=True)
    elif spec.kind == "mamba":
        # Chunked selective scan for long sequences: per-chunk carries
        # instead of per-timestep (the sequential scan would stash the
        # (b, d_inner, d_state) state 4096x for the backward pass).
        chunked = x.shape[1] >= 1024 and x.shape[1] % 256 == 0
        mix = ssm_lib.mamba_apply(bp["mixer"], cfg, h, chunked=chunked,
                                  chunk=256)
    elif spec.kind == "mlstm":
        mix = xlstm_lib.mlstm_apply(bp["mixer"], cfg, h)
    else:
        mix = xlstm_lib.slstm_apply(bp["mixer"], cfg, h)
    x = x + mix
    if "cross" in bp and memory is not None:
        hc = ly.rms_norm(x, bp["norm_cross"], cfg.norm_eps)
        mem_kv = ly.cross_kv(bp["cross"], cfg, memory)
        x = x + ly.attn_apply(bp["cross"], cfg, hc, positions, memory=mem_kv)
    if "ffn" in bp:
        h2 = ly.rms_norm(x, bp["norm2"], cfg.norm_eps)
        if spec.is_moe:
            y, aux = ly.moe_apply(bp["ffn"], cfg, h2)
        else:
            y = ly.mlp_apply(bp["ffn"], h2)
        x = x + y
    return x, aux


def _run_stack(params_blocks, cfg: ModelConfig, specs, repeats: int,
               x: jax.Array, positions: jax.Array,
               memory: jax.Array | None, *, remat: bool) -> tuple[jax.Array, jax.Array]:
    block_fns = []
    for spec in specs:
        def block_fn(bp, x, spec=spec):
            return _block_apply_train(bp, cfg, spec, x, positions, memory)
        # Long periods (Jamba: 8 blocks) additionally remat per block —
        # the period backward otherwise holds all 8 blocks' internals.
        if remat and len(specs) > 1:
            block_fn = jax.checkpoint(block_fn)
        block_fns.append(block_fn)

    def period_body(carry, stacked_slice):
        x, aux = carry
        for p_idx, fn in enumerate(block_fns):
            x, a = fn(stacked_slice[p_idx], x)
            x = constrain(x, "residual")
            aux = aux + a
        return (x, aux), None

    if remat:
        period_body = jax.checkpoint(period_body)

    (x, aux), _ = jax.lax.scan(
        period_body, (x, jnp.zeros((), jnp.float32)), params_blocks,
        length=repeats)
    return x, aux


def _encode(params: Params, cfg: ModelConfig, frames: jax.Array,
            *, remat: bool) -> jax.Array:
    """Bidirectional encoder over frontend embeddings (b, s_enc, d_model)."""
    enc = params["encoder"]
    positions = jnp.arange(frames.shape[1])
    spec = LayerSpec(kind="attn", is_moe=False, has_cross=False)

    def body(x, bp):
        h = ly.rms_norm(x, bp["norm1"], cfg.norm_eps)
        x = x + ly.attn_apply(bp["mixer"], cfg, h, positions, causal=False)
        if "ffn" in bp:
            h2 = ly.rms_norm(x, bp["norm2"], cfg.norm_eps)
            x = x + ly.mlp_apply(bp["ffn"], h2)
        return x, None

    del spec
    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, frames.astype(jnp.dtype(cfg.dtype)),
                        enc["blocks"])
    return ly.rms_norm(x, enc["final_norm"], cfg.norm_eps)


def forward(params: Params, cfg: ModelConfig, batch: dict[str, jax.Array],
            *, remat: bool = False) -> tuple[jax.Array, jax.Array]:
    """Teacher-forcing logits.

    batch: {"tokens": (b, s)} plus, per modality,
      audio:  {"frames":  (b, s_enc, d_model)}  — encoder memory
      vision: {"patches": (b, n_prefix, d_model)} — prefix embeddings
    Returns (logits (b, s_total, vocab), moe aux loss).
    """
    specs, repeats = layer_schedule(cfg)
    tokens = batch["tokens"]
    x = constrain(jnp.take(params["embed"], tokens, axis=0), "residual")

    memory = None
    if cfg.frontend == "audio" and cfg.encoder_layers:
        memory = _encode(params, cfg, batch["frames"], remat=remat)
    elif cfg.frontend == "vision":
        x = jnp.concatenate(
            [batch["patches"].astype(x.dtype), x], axis=1)

    positions = jnp.arange(x.shape[1])
    x, aux = _run_stack(params["blocks"], cfg, specs, repeats, x, positions,
                        memory, remat=remat)
    x = ly.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = constrain(x @ head, "logits")
    return logits, aux


# ---------------------------------------------------------------------------
# Decode state (paged-capacity caches + Twilight shadow structures)
# ---------------------------------------------------------------------------

def _attn_cache_init(cfg: ModelConfig, batch: int, n_max: int) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    hkv, dh = cfg.n_kv_heads, cfg.d_head
    tw = cfg.twilight
    if n_max % tw.page_size:
        raise ValueError(f"cache capacity {n_max} not divisible by page size "
                         f"{tw.page_size}")
    n_pages = n_max // tw.page_size
    cache: Params = {
        "k": jnp.zeros((batch, n_max, hkv, dh), dtype),
        "v": jnp.zeros((batch, n_max, hkv, dh), dtype),
    }
    if tw.enabled:
        # INT4 shadow K cache (+1/8 memory, §4.3) and Quest page metadata.
        cache["qk_packed"] = jnp.zeros((batch, n_max, hkv, dh // 2), jnp.uint8)
        cache["qk_scale"] = jnp.zeros((batch, n_max, hkv, 1), jnp.float32)
        cache["qk_zero"] = jnp.zeros((batch, n_max, hkv, 1), jnp.float32)
        cache["pmax"] = jnp.zeros((batch, n_pages, hkv, dh), dtype)
        cache["pmin"] = jnp.zeros((batch, n_pages, hkv, dh), dtype)
        cache["ds_channels"] = jnp.zeros((hkv, 16), jnp.int32)
        if tw.selector == "h2o":
            # Page-granular accumulated attention mass: decode scatter-adds
            # the pruner's post-top-p weights per page so the H2O selector
            # can rank pages (the serving formulation of H2O — per-token
            # mass has no home in a paged pool, per-page mass does).
            cache["h2o_mass"] = jnp.zeros((batch, n_pages, hkv), jnp.float32)
    return cache


def _mixer_state_init(cfg: ModelConfig, kind: str, batch: int, n_max: int) -> Params:
    if kind == "attn":
        return _attn_cache_init(cfg, batch, n_max)
    if kind == "mamba":
        return ssm_lib.mamba_init_state(cfg, batch)
    if kind == "mlstm":
        return xlstm_lib.mlstm_init_state(cfg, batch)
    if kind == "slstm":
        return xlstm_lib.slstm_init_state(cfg, batch)
    raise ValueError(kind)


def init_decode_state(cfg: ModelConfig, batch: int, n_max: int,
                      *, n_enc: int = 0) -> Params:
    """Decode-time state pytree: per-layer caches stacked per period position."""
    specs, repeats = layer_schedule(cfg)

    def tile(tree):
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (repeats,) + x.shape), tree)

    blocks = []
    for spec in specs:
        st = _mixer_state_init(cfg, spec.kind, batch, n_max)
        if spec.has_cross and spec.kind == "attn":
            dtype = jnp.dtype(cfg.dtype)
            st["cross_k"] = jnp.zeros((batch, n_enc, cfg.n_kv_heads, cfg.d_head),
                                      dtype)
            st["cross_v"] = jnp.zeros((batch, n_enc, cfg.n_kv_heads, cfg.d_head),
                                      dtype)
        blocks.append(tile(st))
    return {"pos": jnp.zeros((), jnp.int32), "blocks": blocks}


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------

def _selection_ctx(cfg: ModelConfig, cache: Params, length: jax.Array
                   ) -> tuple[SelectionContext, quant_lib.QuantizedTensor | None]:
    tw = cfg.twilight
    if not tw.enabled:
        return SelectionContext(None, None, None, length, None), None
    pm = PageMeta(kmax=cache["pmax"], kmin=cache["pmin"], page_size=tw.page_size)
    qkeys = quant_lib.QuantizedTensor(
        packed=cache["qk_packed"], scale=cache["qk_scale"], zero=cache["qk_zero"])
    ctx = SelectionContext(keys=cache["k"], page_meta=pm, accum_scores=None,
                           length=length, ds_channels=cache["ds_channels"],
                           page_mass=cache.get("h2o_mass"))
    return ctx, qkeys


def _h2o_mass_update(mass: jax.Array, tw_out, page_size: int,
                     page_table: jax.Array | None = None,
                     live: jax.Array | None = None) -> jax.Array:
    """Fold one step's post-top-p weights into the page-mass accumulator.

    ``mass`` is (b, n_pages, hkv) for contiguous caches or (num_pages, hkv)
    physical-page keyed for the shared pool (``page_table`` set).  Kept
    candidate slots contribute their group-max estimated weight to the page
    their token lives in; dead engine slots (``live`` false) contribute
    nothing real — their junk lands on the null page, which is never ranked.
    """
    if tw_out.slot_weights is None:
        return mass  # prune disabled: no weights to accumulate
    w = jnp.where(tw_out.pruned_valid, tw_out.slot_weights, 0.0)
    page = tw_out.indices // page_size  # (b, hkv, m) logical pages
    b, hkv, m = page.shape
    if page_table is None:
        b_idx = jnp.arange(b)[:, None, None]
        h_idx = jnp.arange(hkv)[None, :, None]
        return mass.at[b_idx, page, h_idx].add(w)
    if live is not None:
        w = jnp.where(live[:, None, None], w, 0.0)
    pt = jnp.broadcast_to(page_table[:, None, :],
                          (b, hkv, page_table.shape[1]))
    phys = jnp.take_along_axis(pt, page, axis=2)  # (b, hkv, m) physical
    h_idx = jnp.arange(hkv)[None, :, None]
    return mass.at[phys, h_idx].add(w)


def _h2o_mass_window_update(mass: jax.Array, tw_out, page_size: int,
                            page_table: jax.Array,
                            live: jax.Array) -> jax.Array:
    """Window variant of :func:`_h2o_mass_update`: every live position's
    kept weights accumulate (dead positions carry all-False masks, so they
    contribute nothing).  Positions share one candidate buffer, so the
    per-position contributions sum before a single scatter-add."""
    if tw_out.slot_weights is None:
        return mass
    w = jnp.where(tw_out.pruned_valid, tw_out.slot_weights, 0.0).sum(axis=1)
    w = jnp.where(live[:, None, None], w, 0.0)
    page = tw_out.indices // page_size  # (b, hkv, m) logical pages
    b, hkv, m = page.shape
    pt = jnp.broadcast_to(page_table[:, None, :],
                          (b, hkv, page_table.shape[1]))
    phys = jnp.take_along_axis(pt, page, axis=2)
    h_idx = jnp.arange(hkv)[None, :, None]
    return mass.at[phys, h_idx].add(w)


def _run_stats_vec(tw, tw_out, page_table: jax.Array) -> jax.Array:
    """Survivor-run telemetry for one attention layer (zeros when off).

    Runs are measured on *logical* indices: the page table maps whole
    pages, so within-page contiguity and page boundaries — the only two
    things the run structure is made of — are preserved by translation.
    For a window step the union over positions is measured (that is the
    set the fused kernel streams once)."""
    if not tw.collect_run_stats or tw_out.indices is None:
        return jnp.zeros((runs_lib.RUN_STATS_LEN,), jnp.float32)
    kept = tw_out.pruned_valid
    if kept.ndim == 4:
        kept = kept.any(axis=1)
    cand = tw_out.candidate_valid
    if cand is not None and cand.ndim == 4:
        cand = cand.any(axis=1)  # window union — the staged candidate set
    return runs_lib.run_length_stats(kept, tw_out.indices, tw.page_size,
                                     page_table.shape[1], cand_valid=cand)


def _attn_decode(bp: Params, cfg: ModelConfig, x: jax.Array, cache: Params,
                 pos: jax.Array) -> tuple[jax.Array, Params, jax.Array]:
    """x: (b, 1, d_model).  Returns (out, cache, mean pruned budget)."""
    b = x.shape[0]
    positions = jnp.asarray(pos)[None]  # (1,)
    q, k, v = ly.attn_qkv(bp, cfg, x, positions)  # (b,1,hq,dh), (b,1,hkv,dh)
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice(cache["k"], k, (0, pos, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(cache["v"], v, (0, pos, 0, 0))

    tw = cfg.twilight
    if tw.enabled:
        qt = quant_lib.quantize_int4(k.astype(jnp.float32))
        cache["qk_packed"] = jax.lax.dynamic_update_slice(
            cache["qk_packed"], qt.packed, (0, pos, 0, 0))
        cache["qk_scale"] = jax.lax.dynamic_update_slice(
            cache["qk_scale"], qt.scale, (0, pos, 0, 0))
        cache["qk_zero"] = jax.lax.dynamic_update_slice(
            cache["qk_zero"], qt.zero, (0, pos, 0, 0))
        page = pos // tw.page_size
        old_max = jax.lax.dynamic_slice(
            cache["pmax"], (0, page, 0, 0), (b, 1) + cache["pmax"].shape[2:])
        old_min = jax.lax.dynamic_slice(
            cache["pmin"], (0, page, 0, 0), (b, 1) + cache["pmin"].shape[2:])
        fresh = (pos % tw.page_size) == 0
        new_max = jnp.where(fresh, k, jnp.maximum(old_max, k))
        new_min = jnp.where(fresh, k, jnp.minimum(old_min, k))
        cache["pmax"] = jax.lax.dynamic_update_slice(
            cache["pmax"], new_max, (0, page, 0, 0))
        cache["pmin"] = jax.lax.dynamic_update_slice(
            cache["pmin"], new_min, (0, page, 0, 0))

    length = jnp.full((b,), pos + 1, jnp.int32)
    ctx, qkeys = _selection_ctx(cfg, cache, length)
    tw_out = twilight_decode_attention(
        q[:, 0], cache["k"], cache["v"], tw, ctx=ctx, qkeys=qkeys, length=length)
    if "h2o_mass" in cache and tw_out.indices is not None:
        cache["h2o_mass"] = _h2o_mass_update(cache["h2o_mass"], tw_out,
                                             tw.page_size)
    out = tw_out.out.reshape(b, 1, cfg.n_heads * cfg.d_head) @ bp["wo"]
    budget = tw_out.stats.pruned_budget.astype(jnp.float32).mean()
    return out.astype(x.dtype), cache, budget


def _recurrent_mixer_decode(bp: Params, cfg: ModelConfig, kind: str,
                            h: jax.Array, st: Params
                            ) -> tuple[jax.Array, Params]:
    """Single-token step for the non-attention mixers.  h: (b, 1, d_model)."""
    if kind == "mamba":
        mix1, mixer_st = ssm_lib.mamba_decode_step(
            bp, cfg, h[:, 0], {"conv": st["conv"], "ssm": st["ssm"]})
    elif kind == "mlstm":
        keys4 = ("C", "n", "m", "conv")
        mix1, mixer_st = xlstm_lib.mlstm_decode_step(
            bp, cfg, h[:, 0], {k: st[k] for k in keys4})
    else:  # slstm
        keys4 = ("c", "n", "h", "m")
        mix1, mixer_st = xlstm_lib.slstm_decode_step(
            bp, cfg, h[:, 0], {k: st[k] for k in keys4})
    return mix1[:, None], mixer_st


def _block_apply_decode(bp: Params, cfg: ModelConfig, spec: LayerSpec,
                        x: jax.Array, st: Params, pos: jax.Array
                        ) -> tuple[jax.Array, Params, jax.Array]:
    """x: (b, 1, d_model) single-token block step."""
    budget = jnp.zeros((), jnp.float32)
    h = ly.rms_norm(x, bp["norm1"], cfg.norm_eps)
    if spec.kind == "attn":
        mix, st, budget = _attn_decode(bp["mixer"], cfg, h, st, pos)
    else:
        mix, mixer_st = _recurrent_mixer_decode(bp["mixer"], cfg, spec.kind,
                                                h, st)
        st = {**st, **mixer_st}
    x = x + mix

    if "cross" in bp:
        hc = ly.rms_norm(x, bp["norm_cross"], cfg.norm_eps)
        qc, _, _ = ly.attn_qkv(bp["cross"], cfg, hc, None)
        co = full_decode_attention(qc[:, 0], st["cross_k"], st["cross_v"])
        co = co.reshape(x.shape[0], 1, -1) @ bp["cross"]["wo"]
        x = x + co.astype(x.dtype)

    if "ffn" in bp:
        h2 = ly.rms_norm(x, bp["norm2"], cfg.norm_eps)
        if spec.is_moe:
            y, _ = ly.moe_apply(bp["ffn"], cfg, h2)
        else:
            y = ly.mlp_apply(bp["ffn"], h2)
        x = x + y
    return x, st, budget


def decode_step(params: Params, cfg: ModelConfig, state: Params,
                token: jax.Array) -> tuple[jax.Array, Params, dict[str, jax.Array]]:
    """One serving step: token (b,) i32 -> (logits (b, vocab), state, stats)."""
    specs, repeats = layer_schedule(cfg)
    pos = state["pos"]
    x = jnp.take(params["embed"], token, axis=0)[:, None, :]  # (b, 1, d)

    def period_body(carry, xs_slice):
        x, budget_sum, n_attn = carry
        bp_slice, st_slice = xs_slice
        new_states = []
        for p_idx, spec in enumerate(specs):
            x, st, budget = _block_apply_decode(
                bp_slice[p_idx], cfg, spec, x, st_slice[p_idx], pos)
            new_states.append(st)
            if spec.kind == "attn":
                budget_sum = budget_sum + budget
                n_attn = n_attn + 1.0
        return (x, budget_sum, n_attn), new_states

    (x, budget_sum, n_attn), new_blocks = jax.lax.scan(
        period_body,
        (x, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (params["blocks"], state["blocks"]), length=repeats)

    x = ly.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head)[:, 0]
    new_state = {"pos": pos + 1, "blocks": new_blocks}
    stats = {"mean_pruned_budget": budget_sum / jnp.maximum(n_attn, 1.0)}
    return logits, new_state, stats


# ---------------------------------------------------------------------------
# Prefill: full-sequence forward that also fills the decode caches
# ---------------------------------------------------------------------------

def _attn_prefill(bp: Params, cfg: ModelConfig, h: jax.Array,
                  positions: jax.Array, n_max: int) -> tuple[jax.Array, Params]:
    b, s, _ = h.shape
    q, k, v = ly.attn_qkv(bp, cfg, h, positions)
    tw = cfg.twilight
    if tw.enabled and tw.prefill_top_p is not None:
        # Hierarchical top-p sparse prefill: per query block the Quest
        # page upper bound picks a page nucleus and only surviving pages
        # are attended (kernels/sparse_prefill).  The page min/max here
        # equal what the decode cache stores below (tail pages reduce
        # over their resident rows only).  top_p=1.0 statically takes the
        # dense mha_attention bypass inside the wrapper — the bit-exact
        # oracle mode.
        ps = tw.page_size
        n_pad = -(-s // ps) * ps
        kpad = jnp.pad(k, ((0, 0), (0, n_pad - s), (0, 0), (0, 0)))
        vpad = jnp.pad(v, ((0, 0), (0, n_pad - s), (0, 0), (0, 0)))
        neg = jnp.finfo(jnp.float32).min
        live = (jnp.arange(n_pad) < s)[None, :, None, None]
        k32 = kpad.astype(jnp.float32)
        kgrid = (b, n_pad // ps, ps, cfg.n_kv_heads, cfg.d_head)
        kmax = jnp.where(live, k32, neg).reshape(kgrid).max(axis=2)
        kmin = jnp.where(live, k32, -neg).reshape(kgrid).min(axis=2)
        out = sparse_prefill_attend(q, kpad, vpad, kmax, kmin,
                                    top_p=tw.prefill_top_p, page_size=ps,
                                    kv_len=s)
    else:
        out = mha_attention(q, k, v, causal=True)
    out = out.reshape(b, s, cfg.n_heads * cfg.d_head) @ bp["wo"]

    cache = _attn_cache_init(cfg, b, n_max)
    cache["k"] = cache["k"].at[:, :s].set(k)
    cache["v"] = cache["v"].at[:, :s].set(v)
    if tw.enabled:
        qt = quant_lib.quantize_int4(k.astype(jnp.float32))
        cache["qk_packed"] = cache["qk_packed"].at[:, :s].set(qt.packed)
        cache["qk_scale"] = cache["qk_scale"].at[:, :s].set(qt.scale)
        cache["qk_zero"] = cache["qk_zero"].at[:, :s].set(qt.zero)
        ps = tw.page_size
        n_pages_live = s // ps
        if n_pages_live:
            kp = k[:, :n_pages_live * ps].reshape(b, n_pages_live, ps,
                                                  cfg.n_kv_heads, cfg.d_head)
            cache["pmax"] = cache["pmax"].at[:, :n_pages_live].set(kp.max(axis=2))
            cache["pmin"] = cache["pmin"].at[:, :n_pages_live].set(kp.min(axis=2))
        rem = s - n_pages_live * ps
        if rem:
            kt = k[:, n_pages_live * ps:]
            cache["pmax"] = cache["pmax"].at[:, n_pages_live].set(kt.max(axis=1))
            cache["pmin"] = cache["pmin"].at[:, n_pages_live].set(kt.min(axis=1))
        # Double-Sparsity label channels calibrated on this prompt's keys.
        stat = jnp.mean(jnp.abs(k.astype(jnp.float32)), axis=(0, 1))  # (hkv, dh)
        cache["ds_channels"] = jax.lax.top_k(stat, 16)[1].astype(jnp.int32)
    return out.astype(h.dtype), cache


def prefill(params: Params, cfg: ModelConfig, batch: dict[str, jax.Array],
            n_max: int) -> tuple[jax.Array, Params]:
    """Process the prompt, returning (full logits, primed decode state)."""
    specs, repeats = layer_schedule(cfg)
    tokens = batch["tokens"]
    x = constrain(jnp.take(params["embed"], tokens, axis=0), "residual")

    memory = None
    if cfg.frontend == "audio" and cfg.encoder_layers:
        memory = _encode(params, cfg, batch["frames"], remat=False)
    elif cfg.frontend == "vision":
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)

    b, s, _ = x.shape
    positions = jnp.arange(s)

    def period_body(carry, bp_slice):
        x = carry
        new_states = []
        for p_idx, spec in enumerate(specs):
            bp = bp_slice[p_idx]
            h = ly.rms_norm(x, bp["norm1"], cfg.norm_eps)
            if spec.kind == "attn":
                mix, st = _attn_prefill(bp["mixer"], cfg, h, positions, n_max)
            elif spec.kind == "mamba":
                mix, st = ssm_lib.mamba_apply(bp["mixer"], cfg, h,
                                              return_state=True)
            elif spec.kind == "mlstm":
                mix, st = xlstm_lib.mlstm_apply(bp["mixer"], cfg, h,
                                                return_state=True)
            else:
                mix, st = xlstm_lib.slstm_apply(bp["mixer"], cfg, h,
                                                return_state=True)
            x = x + mix
            if "cross" in bp and memory is not None:
                hc = ly.rms_norm(x, bp["norm_cross"], cfg.norm_eps)
                mem_kv = ly.cross_kv(bp["cross"], cfg, memory)
                st["cross_k"], st["cross_v"] = mem_kv
                x = x + ly.attn_apply(bp["cross"], cfg, hc, positions,
                                      memory=mem_kv)
            if "ffn" in bp:
                h2 = ly.rms_norm(x, bp["norm2"], cfg.norm_eps)
                if spec.is_moe:
                    y, _ = ly.moe_apply(bp["ffn"], cfg, h2)
                else:
                    y = ly.mlp_apply(bp["ffn"], h2)
                x = x + y
            new_states.append(st)
        return x, new_states

    x, blocks = jax.lax.scan(period_body, x, params["blocks"], length=repeats)
    x = ly.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = constrain(x @ head, "logits")
    state = {"pos": jnp.asarray(s, jnp.int32), "blocks": blocks}
    return logits, state


# ---------------------------------------------------------------------------
# Paged decode: shared page pool + per-slot page tables (continuous batching)
# ---------------------------------------------------------------------------
#
# Physical page 0 is the null page (``repro.serving.paged_cache.NULL_PAGE``):
# never allocated, the scatter target for dead slots and the safe-gather
# target for invalid index-buffer entries.  All request dynamism — page
# tables, per-slot lengths, the live mask — is *data* passed into the jitted
# step; shapes stay static at (batch, num_pages, max_pages).

_NULL_PAGE = 0


def _attn_pool_init(cfg: ModelConfig, batch: int, num_pages: int) -> Params:
    """Shared K/V (+Twilight shadow) pool for one attention layer.

    ``ds_channels`` is per-*slot* (batch, hkv, r): each request's
    Double-Sparsity label channels are calibrated on its own prompt, so
    admitting one request never perturbs another slot's selection (the
    contiguous cache keeps a single set — wave mates share a prefill)."""
    dtype = jnp.dtype(cfg.dtype)
    hkv, dh = cfg.n_kv_heads, cfg.d_head
    tw = cfg.twilight
    rows = num_pages * tw.page_size
    pool: Params = {
        "k": jnp.zeros((rows, hkv, dh), dtype),
        "v": jnp.zeros((rows, hkv, dh), dtype),
    }
    if tw.enabled:
        pool["qk_packed"] = jnp.zeros((rows, hkv, dh // 2), jnp.uint8)
        pool["qk_scale"] = jnp.zeros((rows, hkv, 1), jnp.float32)
        pool["qk_zero"] = jnp.zeros((rows, hkv, 1), jnp.float32)
        pool["pmax"] = jnp.zeros((num_pages, hkv, dh), dtype)
        pool["pmin"] = jnp.zeros((num_pages, hkv, dh), dtype)
        pool["ds_channels"] = jnp.zeros((batch, hkv, 16), jnp.int32)
        if tw.selector == "h2o":
            # Physical-page H2O mass: shared pages accumulate mass from
            # every reader (prefix sharing pools the signal); pages are
            # zeroed when (re)written fresh so recycled pages never carry a
            # previous occupant's mass.
            pool["h2o_mass"] = jnp.zeros((num_pages, hkv), jnp.float32)
    return pool


def init_paged_decode_state(cfg: ModelConfig, batch: int, num_pages: int,
                            *, n_enc: int = 0) -> Params:
    """Paged decode state: pooled attention caches, per-slot mixer states.

    Unlike :func:`init_decode_state` there is no per-slot capacity — slots
    share the ``num_pages`` pool and address it through engine-managed page
    tables (passed into :func:`decode_step_paged` as data, not stored here).
    """
    specs, repeats = layer_schedule(cfg)

    def tile(tree):
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (repeats,) + x.shape), tree)

    blocks = []
    for spec in specs:
        if spec.kind == "attn":
            st = _attn_pool_init(cfg, batch, num_pages)
        else:
            st = _mixer_state_init(cfg, spec.kind, batch, 0)
        if spec.has_cross and spec.kind == "attn":
            dtype = jnp.dtype(cfg.dtype)
            st["cross_k"] = jnp.zeros(
                (batch, n_enc, cfg.n_kv_heads, cfg.d_head), dtype)
            st["cross_v"] = jnp.zeros(
                (batch, n_enc, cfg.n_kv_heads, cfg.d_head), dtype)
        blocks.append(tile(st))
    return {"blocks": blocks}


def write_prefill_slot(cfg: ModelConfig, state: Params, pstate: Params,
                       slot: jax.Array, page_ids: jax.Array) -> Params:
    """Scatter a batch=1 :func:`prefill` state into pool pages + slot rows.

    ``pstate`` is the contiguous state from ``prefill(..., n_max)`` with
    ``n_max = len(page_ids) * page_size`` (a whole number of pages; rows
    beyond the true prompt length are zeros and stay invalid until decode
    overwrites them).  Attention K/V/INT4 rows and Quest page stats land in
    the physical pages ``page_ids``; recurrent mixer states, cross-attn
    caches, and the Double-Sparsity label channels (calibrated on this
    prompt) land in per-slot row ``slot``.
    """
    specs, _ = layer_schedule(cfg)
    ps = cfg.twilight.page_size
    new_blocks = []
    for spec, pool, src in zip(specs, state["blocks"], pstate["blocks"]):
        new = dict(pool)
        if spec.kind == "attn":
            n_req = page_ids.shape[0]
            for name in ("k", "v", "qk_packed", "qk_scale", "qk_zero"):
                if name not in pool:
                    continue
                rows = src[name]  # (repeats, 1, n_max, hkv, c)
                r, _, n_max = rows.shape[:3]
                tail = rows.shape[3:]
                paged_src = rows.reshape(r, n_req, ps, *tail)
                dst = new[name].reshape(r, -1, ps, *tail)
                new[name] = dst.at[:, page_ids].set(paged_src).reshape(
                    new[name].shape)
            for name in ("pmax", "pmin"):
                if name in pool:
                    new[name] = new[name].at[:, page_ids].set(
                        src[name][:, 0, :n_req])
            if "h2o_mass" in pool:
                # Fresh pages start with zero accumulated mass — recycled
                # pages must not inherit a previous occupant's signal.
                new["h2o_mass"] = new["h2o_mass"].at[:, page_ids].set(0.0)
            if "ds_channels" in pool:
                new["ds_channels"] = new["ds_channels"].at[:, slot].set(
                    src["ds_channels"])
            for name in ("cross_k", "cross_v"):
                if name in pool:
                    new[name] = new[name].at[:, slot].set(src[name][:, 0])
        else:
            new = jax.tree_util.tree_map(
                lambda dst, s: dst.at[:, slot].set(s[:, 0]), pool, src)
        new_blocks.append(new)
    return {"blocks": new_blocks}


def copy_page(cfg: ModelConfig, state: Params, src_page: jax.Array,
              dst_page: jax.Array) -> Params:
    """Device-side page duplication — the copy half of copy-on-write.

    Copies one physical page's token rows (K/V + INT4 shadow) and its
    Quest min/max metadata from ``src_page`` to ``dst_page`` in every
    attention layer's pool.  Page ids are traced scalars, so the engine
    jits this once and reuses it for every COW append.
    """
    specs, _ = layer_schedule(cfg)
    ps = cfg.twilight.page_size
    new_blocks = []
    for spec, pool in zip(specs, state["blocks"]):
        if spec.kind != "attn":
            new_blocks.append(pool)
            continue
        new = dict(pool)
        for name in ("k", "v", "qk_packed", "qk_scale", "qk_zero"):
            if name in pool:
                rows = jax.lax.dynamic_slice_in_dim(
                    pool[name], src_page * ps, ps, axis=1)
                new[name] = jax.lax.dynamic_update_slice_in_dim(
                    pool[name], rows, dst_page * ps, axis=1)
        for name in ("pmax", "pmin", "h2o_mass"):
            if name in pool:
                row = jax.lax.dynamic_slice_in_dim(
                    pool[name], src_page, 1, axis=1)
                new[name] = jax.lax.dynamic_update_slice_in_dim(
                    pool[name], row, dst_page, axis=1)
        new_blocks.append(new)
    return {"blocks": new_blocks}


def supports_chunked_prefill(cfg: ModelConfig) -> bool:
    """Chunked paged prefill (and thus prefix sharing) is attention-only.

    Recurrent mixers (mamba/xLSTM) carry prefix-dependent state — reusing
    cached pages would skip exactly the tokens that state needs, and a
    fixed-size chunk cannot be right-padded without corrupting the scan —
    so hybrid/SSM stacks keep the exact-length prefill path.  Cross-attn /
    modality frontends are excluded for the same reason (encoder memory and
    prefix embeddings are whole-prompt artifacts).
    """
    specs, _ = layer_schedule(cfg)
    tw = cfg.twilight
    return (all(s.kind == "attn" and not s.has_cross for s in specs)
            and cfg.encoder_layers == 0 and cfg.frontend == "none"
            and tw.enabled and tw.compact)


def _attn_prefill_chunk(bp: Params, cfg: ModelConfig, h: jax.Array,
                        cache: Params, page_table: jax.Array,
                        slot: jax.Array, start: jax.Array,
                        n_valid: jax.Array, is_last: jax.Array
                        ) -> tuple[jax.Array, Params, jax.Array]:
    """One attention layer over one prefill chunk, writing pool pages.

    h: (1, C, d_model) — C is the (static, bucketed) chunk length, a
    multiple of page_size.  Tokens ``start .. start + n_valid - 1`` are
    real; the rest is padding whose K/V rows are routed to the null page.
    Attention gathers the slot's whole logical view through its page
    table, so the chunk attends to the already-resident prefix (cached or
    written by earlier chunks) plus itself, causally — or, with
    ``prefill_top_p`` set, block-sparsely against the page-nucleus
    survivors only.  Also returns the (RUN_STATS_LEN,) prefill telemetry
    vector (zeros on the dense path).
    """
    from repro.core.selectors import gather_logical_rows

    _, C, _ = h.shape
    tw = cfg.twilight
    ps = tw.page_size
    max_pages = page_table.shape[0]
    offs = jnp.arange(C)
    pos = start + offs
    q, k, v = ly.attn_qkv(bp, cfg, h, pos)
    k1, v1 = k[0], v[0]  # (C, hkv, d)

    lpage = pos // ps
    phys = jnp.take(page_table, jnp.minimum(lpage, max_pages - 1))
    valid_tok = offs < n_valid
    row = jnp.where(valid_tok, phys * ps + pos % ps, _NULL_PAGE)

    cache = dict(cache)
    cache["k"] = cache["k"].at[row].set(k1)
    cache["v"] = cache["v"].at[row].set(v1)

    if tw.enabled:
        qt = quant_lib.quantize_int4(k1.astype(jnp.float32))
        cache["qk_packed"] = cache["qk_packed"].at[row].set(qt.packed)
        cache["qk_scale"] = cache["qk_scale"].at[row].set(qt.scale)
        cache["qk_zero"] = cache["qk_zero"].at[row].set(qt.zero)
        # Quest metadata for every page the chunk touches.  A page whose
        # first row lies inside the chunk is fresh (overwrite); a page
        # partially filled before this chunk (COW append) merges with its
        # existing stats.  Only j = 0 can be such a boundary page — for
        # j >= 1 the page's first row ``lp * ps = (start // ps + j) * ps``
        # is always >= start, so the merge gathers are skipped statically
        # and the chunk's own reduction overwrites.  Pages with no valid
        # contribution write junk to the null page — never trusted.
        neg = jnp.finfo(jnp.float32).min
        k32 = k1.astype(jnp.float32)
        for j in range(C // ps + 1):
            lp = start // ps + j
            in_page = (lpage == lp) & valid_tok
            any_c = in_page.any()
            sel = in_page[:, None, None]
            kmax_c = jnp.where(sel, k32, neg).max(axis=0)  # (hkv, d)
            kmin_c = jnp.where(sel, k32, -neg).min(axis=0)
            phys_p = jnp.where(
                any_c, jnp.take(page_table, jnp.minimum(lp, max_pages - 1)),
                _NULL_PAGE)
            if j == 0:
                fresh = start % ps == 0
                old_max = jnp.take(cache["pmax"], phys_p, axis=0
                                   ).astype(jnp.float32)
                old_min = jnp.take(cache["pmin"], phys_p, axis=0
                                   ).astype(jnp.float32)
                new_max = jnp.where(fresh, kmax_c,
                                    jnp.maximum(old_max, kmax_c))
                new_min = jnp.where(fresh, kmin_c,
                                    jnp.minimum(old_min, kmin_c))
            else:
                new_max, new_min = kmax_c, kmin_c
            cache["pmax"] = cache["pmax"].at[phys_p].set(
                new_max.astype(cache["pmax"].dtype))
            cache["pmin"] = cache["pmin"].at[phys_p].set(
                new_min.astype(cache["pmin"].dtype))
            if "h2o_mass" in cache:
                # Pages the chunk starts fresh drop any recycled mass; a
                # partially-resident page (COW append) keeps the mass
                # ``copy_page`` carried over from its source.  Same
                # static split: only j = 0 can be partially resident.
                if j == 0:
                    old_mass = jnp.take(cache["h2o_mass"], phys_p, axis=0)
                    cache["h2o_mass"] = cache["h2o_mass"].at[phys_p].set(
                        jnp.where(fresh, 0.0, old_mass))
                else:
                    cache["h2o_mass"] = cache["h2o_mass"].at[phys_p].set(0.0)

    rs = jnp.zeros((runs_lib.RUN_STATS_LEN,), jnp.float32)
    if tw.enabled and tw.prefill_top_p is not None:
        # Sparse chunked prefill: the chunk's query blocks attend only
        # their page-nucleus survivors, streamed straight from the pool
        # through the page table — the O(n) logical K/V gather below is
        # skipped entirely on this path.  top_p=1.0 is the oracle mode:
        # the wrapper's static bypass runs exactly the dense gather +
        # mha_attention of the else branch, bit for bit.
        out, aux = sparse_prefill_attend(
            q, cache["k"], cache["v"], cache["pmax"], cache["pmin"],
            top_p=tw.prefill_top_p, page_size=ps,
            kv_len=start + n_valid, q_offset=start, n_valid=n_valid,
            page_table=page_table[None], return_aux=True)
        rs = runs_lib.prefill_page_stats(aux["survivors"],
                                         aux["participate"])
    else:
        k_log = gather_logical_rows(cache["k"], page_table[None], ps)
        v_log = gather_logical_rows(cache["v"], page_table[None], ps)
        out = mha_attention(q, k_log, v_log, causal=True, q_offset=start)
    out = out.reshape(1, C, cfg.n_heads * cfg.d_head) @ bp["wo"]

    if tw.enabled and "ds_channels" in cache:
        # Per-slot Double-Sparsity calibration over the whole resident
        # prompt (cached prefix + suffix) — equal to the full-prompt
        # calibration the contiguous prefill computes.  Only the final
        # chunk's value is ever read (the slot is not live before then),
        # so earlier chunks skip the O(capacity) reduction entirely.
        def _calibrate(_):
            n_cap = max_pages * ps
            tot = start + n_valid
            live_rows = (jnp.arange(n_cap) < tot)[:, None, None]
            k_cal = gather_logical_rows(cache["k"], page_table[None], ps)
            stat = jnp.sum(
                jnp.where(live_rows,
                          jnp.abs(k_cal[0].astype(jnp.float32)), 0.0),
                axis=0) / tot.astype(jnp.float32)
            return jax.lax.top_k(stat, 16)[1].astype(jnp.int32)

        old_row = jnp.take(cache["ds_channels"], slot, axis=0)
        new_row = jax.lax.cond(is_last, _calibrate, lambda _: old_row, None)
        cache["ds_channels"] = cache["ds_channels"].at[slot].set(new_row)
    return out.astype(h.dtype), cache, rs


def prefill_chunk(params: Params, cfg: ModelConfig, state: Params,
                  tokens: jax.Array, page_table: jax.Array, slot: jax.Array,
                  start: jax.Array, n_valid: jax.Array,
                  is_last: jax.Array | bool = True
                  ) -> tuple[jax.Array, Params, dict[str, jax.Array]]:
    """Prefill one fixed-size chunk of one slot's prompt into pool pages.

    tokens: (C,) i32 (C static, a multiple of page_size — the engine
    buckets ragged tails to a handful of sizes, so the jit cache holds a
    few signatures instead of one per exact prompt length); page_table:
    (max_pages,) i32 physical pages for this slot (pages covering
    ``start .. start + n_valid`` must already be allocated); slot: ()
    engine slot (for per-slot calibration state); start/n_valid: () i32;
    is_last: () bool — the prompt's final chunk (runs the per-slot
    Double-Sparsity calibration, skipped as dead work on earlier chunks).
    Returns (logits (1, C, padded_vocab), state, stats) where stats
    carries ``prefill_run_stats``: the (RUN_STATS_LEN,) sparse-prefill
    live-page telemetry summed over layers (zeros when ``prefill_top_p``
    is off).  Attention-only stacks only — see
    :func:`supports_chunked_prefill`.
    """
    specs, repeats = layer_schedule(cfg)
    if not supports_chunked_prefill(cfg):
        raise ValueError(f"{cfg.name}: chunked paged prefill requires an "
                         "attention-only stack (no recurrent mixers, "
                         "cross-attention, or modality frontend)")
    x = jnp.take(params["embed"], tokens, axis=0)[None]  # (1, C, d)

    def period_body(carry, xs_slice):
        x, rs_sum = carry
        bp_slice, st_slice = xs_slice
        new_states = []
        for p_idx, spec in enumerate(specs):
            bp, st = bp_slice[p_idx], st_slice[p_idx]
            h = ly.rms_norm(x, bp["norm1"], cfg.norm_eps)
            mix, st, rs = _attn_prefill_chunk(bp["mixer"], cfg, h, st,
                                              page_table, slot, start,
                                              n_valid, jnp.asarray(is_last))
            x = x + mix
            rs_sum = rs_sum + rs
            if "ffn" in bp:
                h2 = ly.rms_norm(x, bp["norm2"], cfg.norm_eps)
                if spec.is_moe:
                    y, _ = ly.moe_apply(bp["ffn"], cfg, h2)
                else:
                    y = ly.mlp_apply(bp["ffn"], h2)
                x = x + y
            new_states.append(st)
        return (x, rs_sum), new_states

    (x, rs_sum), new_blocks = jax.lax.scan(
        period_body,
        (x, jnp.zeros((runs_lib.RUN_STATS_LEN,), jnp.float32)),
        (params["blocks"], state["blocks"]), length=repeats)
    x = ly.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return logits, {"blocks": new_blocks}, {"prefill_run_stats": rs_sum}


def _selection_ctx_paged(cfg: ModelConfig, cache: Params,
                         page_table: jax.Array, length: jax.Array
                         ) -> tuple[SelectionContext,
                                    quant_lib.QuantizedTensor | None]:
    tw = cfg.twilight
    pm = PageMeta(kmax=cache["pmax"], kmin=cache["pmin"],
                  page_size=tw.page_size)
    qkeys = quant_lib.QuantizedTensor(
        packed=cache["qk_packed"], scale=cache["qk_scale"],
        zero=cache["qk_zero"])
    ctx = SelectionContext(keys=cache["k"], page_meta=pm, accum_scores=None,
                           length=length, ds_channels=cache["ds_channels"],
                           page_table=page_table,
                           page_mass=cache.get("h2o_mass"))
    return ctx, qkeys


def _attn_decode_paged(bp: Params, cfg: ModelConfig, x: jax.Array,
                       cache: Params, page_table: jax.Array,
                       lengths: jax.Array, live: jax.Array
                       ) -> tuple[jax.Array, Params, jax.Array]:
    """x: (b, 1, d_model) -> (out, cache, per-slot pruned budget (b,)).

    Appends each live slot's token at its own position ``lengths[i]`` —
    physical row ``page_table[i, lengths[i] // ps] * ps + lengths[i] % ps``
    — then runs the compact Twilight pipeline against the pool.  Dead slots
    write the null page and their outputs are garbage by design (the engine
    never samples them).
    """
    b = x.shape[0]
    tw = cfg.twilight
    ps = tw.page_size
    positions = lengths[:, None]  # (b, 1) per-slot RoPE positions
    q, k, v = ly.attn_qkv(bp, cfg, x, positions)
    k1, v1 = k[:, 0], v[:, 0]  # (b, hkv, d)

    lpage = lengths // ps
    phys_page = jnp.take_along_axis(page_table, lpage[:, None], axis=1)[:, 0]
    phys_page = jnp.where(live, phys_page, _NULL_PAGE)
    row = phys_page * ps + lengths % ps  # (b,) pool token rows

    cache = dict(cache)
    cache["k"] = cache["k"].at[row].set(k1)
    cache["v"] = cache["v"].at[row].set(v1)

    if tw.enabled:
        qt = quant_lib.quantize_int4(k1.astype(jnp.float32))
        cache["qk_packed"] = cache["qk_packed"].at[row].set(qt.packed)
        cache["qk_scale"] = cache["qk_scale"].at[row].set(qt.scale)
        cache["qk_zero"] = cache["qk_zero"].at[row].set(qt.zero)
        old_max = jnp.take(cache["pmax"], phys_page, axis=0)  # (b, hkv, d)
        old_min = jnp.take(cache["pmin"], phys_page, axis=0)
        fresh = ((lengths % ps) == 0)[:, None, None]
        new_max = jnp.where(fresh, k1, jnp.maximum(old_max, k1))
        new_min = jnp.where(fresh, k1, jnp.minimum(old_min, k1))
        cache["pmax"] = cache["pmax"].at[phys_page].set(new_max)
        cache["pmin"] = cache["pmin"].at[phys_page].set(new_min)
        if "h2o_mass" in cache:
            # A freshly-started page may be a recycled one: zero its mass
            # before selection so a previous occupant's signal never leaks
            # (matches the contiguous cache, whose rows init to zero).
            old_mass = jnp.take(cache["h2o_mass"], phys_page, axis=0)
            fresh_live = fresh[:, :, 0] & live[:, None]
            cache["h2o_mass"] = cache["h2o_mass"].at[phys_page].set(
                jnp.where(fresh_live, 0.0, old_mass))

    length = lengths + 1
    ctx, qkeys = _selection_ctx_paged(cfg, cache, page_table, length)
    tw_out = twilight_decode_attention(
        q[:, 0], cache["k"], cache["v"], tw, ctx=ctx, qkeys=qkeys,
        length=length)
    if "h2o_mass" in cache and tw_out.indices is not None:
        cache["h2o_mass"] = _h2o_mass_update(
            cache["h2o_mass"], tw_out, ps, page_table=page_table, live=live)
    out = tw_out.out.reshape(b, 1, cfg.n_heads * cfg.d_head) @ bp["wo"]
    budget = tw_out.stats.pruned_budget.astype(jnp.float32).mean(axis=-1)
    rs = _run_stats_vec(tw, tw_out, page_table)
    return out.astype(x.dtype), cache, budget, rs


def _block_apply_decode_paged(bp: Params, cfg: ModelConfig, spec: LayerSpec,
                              x: jax.Array, st: Params,
                              page_table: jax.Array, lengths: jax.Array,
                              live: jax.Array
                              ) -> tuple[jax.Array, Params, jax.Array,
                                         jax.Array]:
    b = x.shape[0]
    budget = jnp.zeros((b,), jnp.float32)
    rs = jnp.zeros((runs_lib.RUN_STATS_LEN,), jnp.float32)
    h = ly.rms_norm(x, bp["norm1"], cfg.norm_eps)
    if spec.kind == "attn":
        mix, st, budget, rs = _attn_decode_paged(
            bp["mixer"], cfg, h, st, page_table, lengths, live)
    else:
        mix, mixer_st = _recurrent_mixer_decode(bp["mixer"], cfg, spec.kind,
                                                h, st)
        # Freeze dead slots' recurrent state: junk evolution could overflow
        # over long idle stretches, and admission overwrites it anyway.
        gated = jax.tree_util.tree_map(
            lambda new, old: jnp.where(
                live.reshape((b,) + (1,) * (new.ndim - 1)), new, old),
            mixer_st, {k: st[k] for k in mixer_st})
        st = {**st, **gated}
    x = x + mix

    if "cross" in bp:
        hc = ly.rms_norm(x, bp["norm_cross"], cfg.norm_eps)
        qc, _, _ = ly.attn_qkv(bp["cross"], cfg, hc, None)
        co = full_decode_attention(qc[:, 0], st["cross_k"], st["cross_v"])
        co = co.reshape(x.shape[0], 1, -1) @ bp["cross"]["wo"]
        x = x + co.astype(x.dtype)

    if "ffn" in bp:
        h2 = ly.rms_norm(x, bp["norm2"], cfg.norm_eps)
        if spec.is_moe:
            y, _ = ly.moe_apply(bp["ffn"], cfg, h2)
        else:
            y = ly.mlp_apply(bp["ffn"], h2)
        x = x + y
    return x, st, budget, rs


def decode_step_paged(params: Params, cfg: ModelConfig, state: Params,
                      token: jax.Array, page_table: jax.Array,
                      lengths: jax.Array, live: jax.Array
                      ) -> tuple[jax.Array, Params, dict[str, jax.Array]]:
    """One continuous-batching step.

    token: (b,) i32; page_table: (b, max_pages) i32 physical page ids;
    lengths: (b,) i32 current per-slot sequence lengths (the position this
    token is written at); live: (b,) bool slot occupancy.  Returns
    (logits (b, vocab), state, stats) with per-slot ``pruned_budget`` (b,)
    and, when ``cfg.twilight.collect_run_stats``, a summed ``run_stats``
    telemetry vector (:data:`repro.core.runs.RUN_STATS_LEN`,).
    """
    specs, repeats = layer_schedule(cfg)
    b = token.shape[0]
    x = jnp.take(params["embed"], token, axis=0)[:, None, :]  # (b, 1, d)

    def period_body(carry, xs_slice):
        x, budget_sum, n_attn, rs_sum = carry
        bp_slice, st_slice = xs_slice
        new_states = []
        for p_idx, spec in enumerate(specs):
            x, st, budget, rs = _block_apply_decode_paged(
                bp_slice[p_idx], cfg, spec, x, st_slice[p_idx],
                page_table, lengths, live)
            new_states.append(st)
            if spec.kind == "attn":
                budget_sum = budget_sum + budget
                n_attn = n_attn + 1.0
                rs_sum = rs_sum + rs
        return (x, budget_sum, n_attn, rs_sum), new_states

    (x, budget_sum, n_attn, rs_sum), new_blocks = jax.lax.scan(
        period_body,
        (x, jnp.zeros((b,), jnp.float32), jnp.zeros((), jnp.float32),
         jnp.zeros((runs_lib.RUN_STATS_LEN,), jnp.float32)),
        (params["blocks"], state["blocks"]), length=repeats)

    x = ly.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head)[:, 0]
    stats = {"pruned_budget": budget_sum / jnp.maximum(n_attn, 1.0)}
    if cfg.twilight.collect_run_stats:
        stats["run_stats"] = rs_sum
    return logits, {"blocks": new_blocks}, stats


def _attn_decode_window_paged(bp: Params, cfg: ModelConfig, x: jax.Array,
                              cache: Params, page_table: jax.Array,
                              lengths: jax.Array, live: jax.Array,
                              n_tok: jax.Array
                              ) -> tuple[jax.Array, Params, jax.Array,
                                         jax.Array]:
    """x: (b, kw, d_model) -> (out (b, kw, d_model), cache, budget, runs).

    Multi-token paged decode: position ``j`` of slot ``i`` lands at
    ``lengths[i] + j``.  Cache rows, page extrema and INT4 shadows are
    appended per position in window order (later positions see earlier
    ones' extrema, exactly as k successive single steps would); positions
    ``j >= n_tok[i]`` and dead slots write the null page.  Attention for
    all kw positions then runs through ONE
    :func:`twilight_decode_window_attention` launch sharing one candidate
    buffer.
    """
    b, kw = x.shape[0], x.shape[1]
    tw = cfg.twilight
    ps = tw.page_size
    positions = lengths[:, None] + jnp.arange(kw)[None, :]  # (b, kw)
    q, k, v = ly.attn_qkv(bp, cfg, x, positions)  # (b, kw, h, d)

    cache = dict(cache)
    for j in range(kw):
        live_j = live & (j < n_tok)
        kj, vj = k[:, j], v[:, j]  # (b, hkv, d)
        pos_j = lengths + j
        lpage = pos_j // ps
        phys_page = jnp.take_along_axis(page_table, lpage[:, None],
                                        axis=1)[:, 0]
        phys_page = jnp.where(live_j, phys_page, _NULL_PAGE)
        row = phys_page * ps + pos_j % ps
        cache["k"] = cache["k"].at[row].set(kj)
        cache["v"] = cache["v"].at[row].set(vj)
        if tw.enabled:
            qt = quant_lib.quantize_int4(kj.astype(jnp.float32))
            cache["qk_packed"] = cache["qk_packed"].at[row].set(qt.packed)
            cache["qk_scale"] = cache["qk_scale"].at[row].set(qt.scale)
            cache["qk_zero"] = cache["qk_zero"].at[row].set(qt.zero)
            old_max = jnp.take(cache["pmax"], phys_page, axis=0)
            old_min = jnp.take(cache["pmin"], phys_page, axis=0)
            fresh = ((pos_j % ps) == 0)[:, None, None]
            new_max = jnp.where(fresh, kj, jnp.maximum(old_max, kj))
            new_min = jnp.where(fresh, kj, jnp.minimum(old_min, kj))
            cache["pmax"] = cache["pmax"].at[phys_page].set(new_max)
            cache["pmin"] = cache["pmin"].at[phys_page].set(new_min)
            if "h2o_mass" in cache:
                old_mass = jnp.take(cache["h2o_mass"], phys_page, axis=0)
                fresh_live = fresh[:, :, 0] & live_j[:, None]
                cache["h2o_mass"] = cache["h2o_mass"].at[phys_page].set(
                    jnp.where(fresh_live, 0.0, old_mass))

    ctx, qkeys = _selection_ctx_paged(cfg, cache, page_table,
                                      lengths + n_tok)
    tw_out = twilight_decode_window_attention(
        q, cache["k"], cache["v"], tw, ctx=ctx, qkeys=qkeys,
        lengths=lengths, n_tok=n_tok)
    if "h2o_mass" in cache and tw_out.indices is not None:
        cache["h2o_mass"] = _h2o_mass_window_update(
            cache["h2o_mass"], tw_out, ps, page_table, live)
    out = tw_out.out.reshape(b, kw, cfg.n_heads * cfg.d_head) @ bp["wo"]
    budget = tw_out.stats.pruned_budget.astype(jnp.float32).mean(axis=-1)
    rs = _run_stats_vec(tw, tw_out, page_table)
    return out.astype(x.dtype), cache, budget, rs


def _block_apply_decode_window_paged(bp: Params, cfg: ModelConfig,
                                     spec: LayerSpec, x: jax.Array,
                                     st: Params, page_table: jax.Array,
                                     lengths: jax.Array, live: jax.Array,
                                     n_tok: jax.Array
                                     ) -> tuple[jax.Array, Params,
                                                jax.Array, jax.Array]:
    if spec.kind != "attn" or "cross" in bp:
        raise ValueError(
            f"{cfg.name}: window decode requires an attention-only stack "
            f"(got a {spec.kind!r} mixer"
            + (" with cross-attention" if "cross" in bp else "") + ")")
    h = ly.rms_norm(x, bp["norm1"], cfg.norm_eps)
    mix, st, budget, rs = _attn_decode_window_paged(
        bp["mixer"], cfg, h, st, page_table, lengths, live, n_tok)
    x = x + mix
    if "ffn" in bp:
        h2 = ly.rms_norm(x, bp["norm2"], cfg.norm_eps)
        if spec.is_moe:
            y, _ = ly.moe_apply(bp["ffn"], cfg, h2)
        else:
            y = ly.mlp_apply(bp["ffn"], h2)
        x = x + y
    return x, st, budget, rs


def decode_window_paged(params: Params, cfg: ModelConfig, state: Params,
                        tokens: jax.Array, page_table: jax.Array,
                        lengths: jax.Array, live: jax.Array,
                        n_tok: jax.Array
                        ) -> tuple[jax.Array, Params, dict[str, jax.Array]]:
    """One continuous-batching step decoding up to kw tokens per slot.

    tokens: (b, kw) i32 — position ``j`` is written at ``lengths[i] + j``;
    n_tok: (b,) i32 in [1, kw], the number of live window positions per
    slot (forced/replayed tokens beyond the first; columns >= n_tok are
    ignored).  Returns (logits (b, kw, vocab), state, stats); logits row
    ``n_tok[i] - 1`` is the sampling row for slot ``i``.  Requires an
    attention-only stack (see ``supports_chunked_prefill``).
    """
    specs, repeats = layer_schedule(cfg)
    b, kw = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)  # (b, kw, d)

    def period_body(carry, xs_slice):
        x, budget_sum, n_attn, rs_sum = carry
        bp_slice, st_slice = xs_slice
        new_states = []
        for p_idx, spec in enumerate(specs):
            x, st, budget, rs = _block_apply_decode_window_paged(
                bp_slice[p_idx], cfg, spec, x, st_slice[p_idx],
                page_table, lengths, live, n_tok)
            new_states.append(st)
            budget_sum = budget_sum + budget
            n_attn = n_attn + 1.0
            rs_sum = rs_sum + rs
        return (x, budget_sum, n_attn, rs_sum), new_states

    (x, budget_sum, n_attn, rs_sum), new_blocks = jax.lax.scan(
        period_body,
        (x, jnp.zeros((b,), jnp.float32), jnp.zeros((), jnp.float32),
         jnp.zeros((runs_lib.RUN_STATS_LEN,), jnp.float32)),
        (params["blocks"], state["blocks"]), length=repeats)

    x = ly.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head  # (b, kw, vocab)
    stats = {"pruned_budget": budget_sum / jnp.maximum(n_attn, 1.0)}
    if cfg.twilight.collect_run_stats:
        stats["run_stats"] = rs_sum
    return logits, {"blocks": new_blocks}, stats
