"""Pallas TPU kernels for the Twilight hot path (§4.2).

Each kernel is a subpackage with ``kernel.py`` (pl.pallas_call +
BlockSpec), ``ops.py`` (jit'd public wrapper) and ``ref.py`` (pure-jnp
oracle used by the tests):

* ``quant``          — INT4 asymmetric quantization + nibble packing of K.
* ``spgemv``         — q · K̃ᵀ score estimation over the packed INT4 cache,
                       dequantization folded into the matmul epilogue.
* ``topp``           — Algorithm 1 binary-search threshold over weight rows.
* ``sparse_attn``    — single-query flash-decode attention with top-p mask
                       and page-granular early-out.
* ``fused_decode``   — estimate→top-p→attend in one launch per decode step
                       (run-coalesced, double-buffered survivor DMA).
* ``sparse_prefill`` — page-nucleus block-sparse flash prefill for the
                       TTFT path (per-query-block survivor sets).

All kernels run under ``interpret=True`` on CPU (how this container
validates them) and compile for TPU with MXU/VPU-aligned tiles.
"""
